"""Dynamic cell-queue scheduling end to end: CLI surface, CLI-to-gate
plumbing, the steal decision rule, and the tier-1 acceptance contract — the
queue-mode merged leaderboard is byte-identical to the static ``--shard
i/n`` + ``merge_db`` flow on the same grid, under an injected mid-lease
kill (cell re-leased exactly once, no datapoint double-recorded) and under
a forced work steal (straggler shard, ``steals >= 1``)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch import campaign as camp
from repro.launch import orchestrator as orch
from repro.launch.scheduler import CellQueue

REPO = Path(__file__).resolve().parents[1]
TINY_PRELUDE_FILE = REPO / "tests" / "ci" / "tiny_prelude.py"
STRAGGLER_PRELUDE_FILE = REPO / "tests" / "ci" / "straggler_prelude.py"

GRID = dict(archs="qwen3-0.6b,stablelm-3b", shapes="train_4k,decode_32k",
            mesh="tiny", iterations=1, budget=2, workers=1)


# ---------------------------------------------------------------------------
# CLI surface (no jax, no subprocesses)
# ---------------------------------------------------------------------------
def test_campaign_parser_queue_flags_and_exclusions():
    ns = camp.build_parser().parse_args(
        ["--queue", "artifacts/q", "--queue-owner", "w0"])
    assert ns.queue == "artifacts/q" and ns.queue_owner == "w0"
    assert ns.queue_lease_s == 300.0 and ns.queue_poll_s == 0.5
    ns2 = camp.build_parser().parse_args(
        ["--gate-factor", "3.0", "--gate-min-factor", "1.5"])
    assert ns2.gate_min_factor == 1.5


def test_run_campaign_rejects_queue_plus_shard_and_bad_gate_specs(tmp_path):
    with pytest.raises(ValueError, match="mutually exclusive"):
        camp.run_campaign(["a"], ["s"], None, "m", out_dir=tmp_path,
                          shard=(0, 2), queue=tmp_path / "q")
    with pytest.raises(ValueError, match="gate-min-factor requires"):
        camp.run_campaign(["a"], ["s"], None, "m", out_dir=tmp_path,
                          gate_min_factor=1.5)
    with pytest.raises(ValueError, match="gate-factor must be > 1"):
        camp.run_campaign(["a"], ["s"], None, "m", out_dir=tmp_path,
                          gate_factor=0.5)
    # the API path enforces the full range check, same as the CLIs
    with pytest.raises(ValueError, match="gate-min-factor must be in"):
        camp.run_campaign(["a"], ["s"], None, "m", out_dir=tmp_path,
                          gate_factor=3.0, gate_min_factor=0.5)
    with pytest.raises(ValueError, match="queue_poll_s"):
        camp.run_campaign(["a"], ["s"], None, "m", out_dir=tmp_path,
                          queue=tmp_path / "q", queue_poll_s=0)


def test_validate_gate_args_is_the_single_source_of_truth():
    assert camp.validate_gate_args(None, None) is None
    assert camp.validate_gate_args(3.0, None) is None
    assert camp.validate_gate_args(3.0, 1.5) is None
    assert camp.validate_gate_args(3.0, 3.0) is None  # inclusive upper edge
    assert "must be > 1" in camp.validate_gate_args(1.0, None)
    assert "requires" in camp.validate_gate_args(None, 1.5)
    assert "must be in" in camp.validate_gate_args(3.0, 1.0)
    assert "must be in" in camp.validate_gate_args(3.0, 3.5)


def test_orchestrator_parser_queue_and_steal_flags():
    ns = orch.build_parser().parse_args(["--queue", "--steal-factor", "3",
                                         "--steal-min-s", "5",
                                         "--max-steals", "1",
                                         "--queue-lease-s", "60"])
    assert ns.queue and ns.steal_factor == 3.0 and ns.steal_min_s == 5.0
    assert ns.max_steals == 1 and ns.queue_lease_s == 60.0
    assert not orch.build_parser().parse_args([]).queue  # static by default


def test_build_shard_cmd_queue_variant_parses_and_names_owner(tmp_path):
    cmd = orch.build_shard_cmd(
        1, 3, tmp_path / "s1", archs="all", shapes="train_4k", mesh="tiny",
        iterations=2, budget=3, workers=1, strategy="ensemble",
        gate_factor=2.5, gate_min_factor=1.5, llm="mock",
        queue_dir=tmp_path / "q", queue_lease_s=120.0)
    assert "--shard" not in cmd  # the queue replaces the static cut
    assert cmd[cmd.index("--queue") + 1] == str((tmp_path / "q").resolve())
    assert cmd[cmd.index("--queue-owner") + 1] == "shard1"
    assert cmd[cmd.index("--queue-lease-s") + 1] == "120.0"
    assert cmd[cmd.index("--gate-min-factor") + 1] == "1.5"
    camp.build_parser().parse_args(cmd[3:])  # must parse against the CLI
    # and the static variant still carries --shard, never --queue
    static = orch.build_shard_cmd(
        1, 3, tmp_path / "s1", archs="all", shapes="train_4k", mesh="tiny",
        iterations=2, budget=3, workers=1, strategy="ensemble",
        gate_factor=None, llm="mock")
    assert "--queue" not in static and static[static.index("--shard") + 1] == "1/3"


def test_orchestrator_rejects_queue_with_relocated_remote_root(tmp_path):
    with pytest.raises(ValueError, match="shared filesystem"):
        orch.run_orchestrator(archs="qwen3-0.6b", shapes="train_4k",
                              shards=1, out_dir=tmp_path / "x", queue=True,
                              executor="ssh", hosts=["h0"],
                              remote_root="/scratch/elsewhere")


# ---------------------------------------------------------------------------
# CLI-to-gate plumbing: --gate-min-factor reaches SurrogateGate.min_factor
# ---------------------------------------------------------------------------
def test_campaign_main_forwards_queue_and_gate_args(monkeypatch):
    captured = {}
    monkeypatch.setattr(camp, "run_campaign",
                        lambda *a, **kw: captured.update(kw))
    monkeypatch.setattr(camp, "make_campaign_mesh",
                        lambda name: (None, "tiny1x1"))
    monkeypatch.setattr(sys, "argv",
                        ["campaign", "--archs", "qwen3-0.6b", "--shapes",
                         "train_4k", "--queue", "artifacts/q",
                         "--queue-owner", "w7", "--queue-lease-s", "77",
                         "--gate-factor", "3.0", "--gate-min-factor", "1.5"])
    camp.main()
    assert captured["queue"] == "artifacts/q"
    assert captured["queue_owner"] == "w7"
    assert captured["queue_lease_s"] == 77.0
    assert captured["gate_factor"] == 3.0
    assert captured["gate_min_factor"] == 1.5


def test_campaign_main_rejects_bad_gate_and_queue_combos(monkeypatch):
    for argv in (["campaign", "--gate-min-factor", "1.5"],
                 ["campaign", "--gate-factor", "3", "--gate-min-factor", "9"],
                 ["campaign", "--queue", "q", "--shard", "0/2"],
                 ["campaign", "--queue", "q", "--queue-lease-s", "0"],
                 ["campaign", "--queue", "q", "--queue-poll-s", "0"]):
        monkeypatch.setattr(sys, "argv", argv)
        with pytest.raises(SystemExit):
            camp.main()


def test_run_campaign_builds_gate_with_min_factor(tmp_path, monkeypatch):
    """The whole chain: run_campaign(gate_factor, gate_min_factor) must
    construct SurrogateGate(factor, min_factor) — verified by intercepting
    the construction (and aborting the campaign right there, before any
    compile)."""
    import repro.search as S

    seen = {}

    class _Stop(RuntimeError):
        pass

    class Recorder:
        def __init__(self, cost_model, factor=None, min_factor=None, **kw):
            seen.update(factor=factor, min_factor=min_factor)
            raise _Stop

    monkeypatch.setattr(S, "SurrogateGate", Recorder)
    with pytest.raises(_Stop):
        camp.run_campaign(["qwen3-0.6b"], ["train_4k"], None, "tiny1x1",
                          out_dir=tmp_path, gate_factor=2.5,
                          gate_min_factor=1.25, verbose=False)
    assert seen == {"factor": 2.5, "min_factor": 1.25}


def test_dse_parser_accepts_gate_min_factor():
    from repro.launch.dse import build_parser

    ns = build_parser().parse_args(["--arch", "llama3-8b", "--shape",
                                    "train_4k", "--gate-factor", "3.0",
                                    "--gate-min-factor", "2.0"])
    assert ns.gate_min_factor == 2.0


# ---------------------------------------------------------------------------
# the steal rule, as a pure decision function
# ---------------------------------------------------------------------------
def _fleet(tmp_path, payloads):
    states = []
    for i, payload in enumerate(payloads):
        s = orch.ShardProc(index=i, out_dir=tmp_path / f"s{i}", cmd=[],
                           env={})
        s.last_payload = payload
        states.append(s)
    return states


def _queue_with_history(tmp_path, *, done_durations=(2.0, 2.0, 3.0),
                        lease_age=100.0, now=1000.0, max_steals_used=0):
    """A queue where shard0 holds one old lease and the fleet has completed
    cells of known duration."""
    q = CellQueue(tmp_path / "q", lease_s=10_000.0)
    cells = [("done", f"s{i}") for i in range(len(done_durations))]
    cells.append(("slowarch", "sx"))
    q.seed(cells)
    for i, d in enumerate(done_durations):
        t = q.acquire("shard1", now=500.0)
        q.complete(t, now=500.0 + d)
    t = q.acquire("shard0", now=now - lease_age)
    if max_steals_used:
        # simulate prior steals without touching the live lease
        t.steals = max_steals_used
        q.renew(t, now=now - lease_age)
    return q


def test_plan_steals_steals_old_lease_when_a_shard_idles(tmp_path):
    q = _queue_with_history(tmp_path)
    states = _fleet(tmp_path, [{"status": "running"}, {"status": "waiting"}])
    out = orch.plan_steals(q, states, steal_factor=4.0, steal_min_s=20.0,
                           max_steals=2, now=1000.0)
    assert [t.cell for t in out] == ["slowarch/sx"]
    # and the actual steal moves it back to pending with the audit trail
    assert q.steal(out[0]) is not None
    assert q.counts()["pending"] == 1


def test_plan_steals_needs_an_idle_taker(tmp_path):
    q = _queue_with_history(tmp_path)
    states = _fleet(tmp_path, [{"status": "running"}, {"status": "running"}])
    assert orch.plan_steals(q, states, steal_factor=4.0, steal_min_s=20.0,
                            max_steals=2, now=1000.0) == []


def test_plan_steals_respects_age_threshold_and_median(tmp_path):
    q = _queue_with_history(tmp_path, lease_age=15.0)
    states = _fleet(tmp_path, [{"status": "running"}, {"status": "waiting"}])
    # age 15 < max(steal_min_s=20, 4 x median 2) = 20: too young
    assert orch.plan_steals(q, states, steal_factor=4.0, steal_min_s=20.0,
                            max_steals=2, now=1000.0) == []
    # a lower floor puts the threshold at 4 x 2 = 8 < 15: steal
    assert len(orch.plan_steals(q, states, steal_factor=4.0, steal_min_s=5.0,
                                max_steals=2, now=1000.0)) == 1


def test_plan_steals_without_completed_cells_never_fires(tmp_path):
    q = CellQueue(tmp_path / "q", lease_s=10_000.0)
    q.seed([("a", "s")])
    q.acquire("shard0", now=0.0)
    states = _fleet(tmp_path, [{"status": "running"}, {"status": "waiting"}])
    assert orch.plan_steals(q, states, steal_factor=1.0, steal_min_s=0.1,
                            max_steals=2, now=10_000.0) == []


def test_plan_steals_honors_per_cell_budget(tmp_path):
    q = _queue_with_history(tmp_path, max_steals_used=2)
    states = _fleet(tmp_path, [{"status": "running"}, {"status": "waiting"}])
    assert orch.plan_steals(q, states, steal_factor=4.0, steal_min_s=5.0,
                            max_steals=2, now=1000.0) == []


def test_plan_steals_never_steals_from_an_idle_owner(tmp_path):
    q = _queue_with_history(tmp_path)
    states = _fleet(tmp_path, [{"status": "waiting"}, {"status": "waiting"}])
    assert orch.plan_steals(q, states, steal_factor=4.0, steal_min_s=5.0,
                            max_steals=2, now=1000.0) == []


# ---------------------------------------------------------------------------
# the acceptance contract, end to end (real subprocesses, tiny configs)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def static_reference(tmp_path_factory):
    """The manual ``--shard i/n`` + ``merge_db`` flow on GRID: the byte
    reference every queue-mode run must reproduce."""
    tmp = tmp_path_factory.mktemp("static_ref")
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "REPRO_CAMPAIGN_PRELUDE": str(TINY_PRELUDE_FILE)}
    for i in range(2):
        cmd = orch.build_shard_cmd(
            i, 2, tmp / f"manual{i}", archs=GRID["archs"],
            shapes=GRID["shapes"], mesh=GRID["mesh"],
            iterations=GRID["iterations"], budget=GRID["budget"],
            workers=GRID["workers"], strategy="ensemble", gate_factor=None,
            llm="mock")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    from repro.launch.merge_db import merge

    merge([tmp / "manual0", tmp / "manual1"], tmp / "merged", verbose=False)
    return (tmp / "merged" / "leaderboard.json").read_bytes()


def _merged_db_identities(out_dir: Path):
    rows = [json.loads(ln) for ln in
            (out_dir / "cost_db.jsonl").read_text().splitlines()
            if ln.strip()]
    return [(r["arch"], r["shape"], r["mesh"], r["point"].get("__key__"),
             r["status"]) for r in rows]


@pytest.mark.slow
def test_queue_mode_heals_mid_lease_kill_byte_identically(
        tmp_path, monkeypatch, static_reference):
    """Fault-injection matrix, kill arm: crash shard 0 mid-lease (after one
    completed cell). The supervisor must restart it and release its lease;
    the cell must be re-leased exactly once (attempt == 2); no datapoint
    may be double-recorded in the merged DB; the summary's restart/steal
    counters must match the injected schedule; and the merged leaderboard
    must be byte-identical to the static shard+merge flow."""
    monkeypatch.setenv("REPRO_CAMPAIGN_PRELUDE", str(TINY_PRELUDE_FILE))
    s = orch.run_orchestrator(shards=2, out_dir=tmp_path / "run", queue=True,
                              inject_kill=(0, 1), poll_interval=0.2,
                              hang_timeout=300.0, verbose=False, **GRID)
    # counters match the injected schedule: one crash, one restart, the
    # killed shard's lease reclaimed, and no steal anywhere
    assert s["restarts"] == 1 and s["restarts_per_shard"]["shard0"] == 1, s
    assert s["steals"] == 0 and s["lease_reclaims"] >= 1, s
    assert s["queue_cells"] == {"pending": 0, "leased": 0, "done": 4}, s

    q = CellQueue(tmp_path / "run" / orch.QUEUE_DIR)
    attempts = sorted(t.attempt for t in q.tickets("done"))
    assert attempts == [1, 1, 1, 2], attempts  # re-leased exactly once
    assert s["max_lease_attempts"] == 2, s
    assert all(t.steals == 0 for t in q.tickets("done"))

    # no datapoint double-recorded in the merged DB
    idents = _merged_db_identities(tmp_path / "run")
    assert len(idents) == len(set(idents)), "double-recorded datapoint"

    # and the acceptance bytes
    got = (tmp_path / "run" / "leaderboard.json").read_bytes()
    assert got == static_reference, (got[:300], static_reference[:300])


@pytest.mark.slow
def test_queue_mode_steals_from_straggler_byte_identically(
        tmp_path, monkeypatch, static_reference):
    """Work stealing, forced: shard 0 sleeps 10s per evaluation (straggler
    prelude) while shard 1 races through the rest of the grid and idles.
    The orchestrator must steal the straggler's stuck cell (>= 1 steal, no
    restart), the stolen cell's audit trail must show the second lease,
    and the merged leaderboard must still be byte-identical to the static
    flow — a stolen cell's double results dedupe at merge."""
    monkeypatch.setenv("REPRO_CAMPAIGN_PRELUDE", str(STRAGGLER_PRELUDE_FILE))
    monkeypatch.setenv("REPRO_TEST_STRAGGLER_SHARD", "0")
    monkeypatch.setenv("REPRO_TEST_EVAL_SLEEP_S", "10")
    s = orch.run_orchestrator(shards=2, out_dir=tmp_path / "run", queue=True,
                              steal_min_s=6.0, steal_factor=2.0,
                              poll_interval=0.2, hang_timeout=300.0,
                              verbose=False, **GRID)
    assert s["steals"] >= 1 and s["restarts"] == 0, s
    assert s["queue_cells"] == {"pending": 0, "leased": 0, "done": 4}, s

    q = CellQueue(tmp_path / "run" / orch.QUEUE_DIR)
    stolen = [t for t in q.tickets("done") if t.steals >= 1]
    assert stolen and all(t.attempt >= 2 for t in stolen), \
        [(t.cell, t.attempt, t.steals) for t in q.tickets("done")]

    idents = _merged_db_identities(tmp_path / "run")
    assert len(idents) == len(set(idents)), "double-recorded datapoint"

    got = (tmp_path / "run" / "leaderboard.json").read_bytes()
    assert got == static_reference, (got[:300], static_reference[:300])
