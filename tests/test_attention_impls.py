"""Attention implementations agree; tri scan reduces FLOPs as designed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.hlo_analysis import analyze_hlo
from repro.models.layers import chunked_attention, chunked_attention_tri


def _qkv(s, h, kh, d, b=2):
    return (0.3 * jax.random.normal(jax.random.key(1), (b, s, h, d)),
            0.3 * jax.random.normal(jax.random.key(2), (b, s, kh, d)),
            0.3 * jax.random.normal(jax.random.key(3), (b, s, kh, d)))


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([64, 100, 128, 200]),
       window=st.sampled_from([None, 24, 48]),
       chunk=st.sampled_from([32, 64]))
def test_tri_matches_chunked(s, window, chunk):
    q, k, v = _qkv(s, 8, 4, 32)
    want = chunked_attention(q, k, v, causal=True, window=window,
                             q_chunk=chunk, k_chunk=chunk)
    got = chunked_attention_tri(q, k, v, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-4)


def test_tri_grads_finite():
    q, k, v = _qkv(96, 4, 4, 16)
    g = jax.grad(lambda q: chunked_attention_tri(q, k, v, chunk=32).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


def test_tri_halves_attention_flops():
    q, k, v = _qkv(512, 4, 4, 32, b=1)
    f = {}
    for nm, fn in {
        "chunked": lambda q: chunked_attention(q, k, v, causal=True,
                                               q_chunk=64, k_chunk=64),
        "tri": lambda q: chunked_attention_tri(q, k, v, chunk=64),
    }.items():
        comp = jax.jit(fn).lower(q).compile()
        f[nm] = analyze_hlo(comp.as_text())["flops"]
    n = 512 // 64
    expect = (n * (n + 1) / 2) / (n * n)  # 36/64
    assert f["tri"] / f["chunked"] == pytest.approx(expect, rel=0.15)


def test_tri_banded_swa_flops():
    """Sliding window: tri computes O(s*w) blocks, not O(s^2)."""
    q, k, v = _qkv(1024, 2, 2, 16, b=1)
    full = jax.jit(lambda q: chunked_attention_tri(q, k, v, chunk=64)).lower(q).compile()
    band = jax.jit(lambda q: chunked_attention_tri(q, k, v, window=128,
                                                   chunk=64)).lower(q).compile()
    ff = analyze_hlo(full.as_text())["flops"]
    fb = analyze_hlo(band.as_text())["flops"]
    assert fb < 0.45 * ff
