"""Property tests for Pareto-front campaigns: the merged front is a
*function of the shard contents*. For generated shard DBs with overlapping
identities and genuinely multi-objective rows (bound/HBM/MFU trade-offs),
any permutation of the shard list must rebuild byte-identical Pareto
leaderboards, re-merging must be a fixed point, and no dominated design
may ever appear in a front regardless of insertion order. Pure file
manipulation — no jax, no subprocesses."""
import itertools
import json
from pathlib import Path

from _hypothesis_compat import given, settings, strategies as st
from repro.core.cost_db import CostDB, DataPoint, objectives_of, pareto_rows
from repro.core.pareto import dominates
from repro.launch.merge_db import merge

ARCHS = ["a1", "a2"]
KEYS = ["k1", "k2", "k3", "k4"]


def _dp(arch, key, ts, bound, hbm, mfu, status="ok"):
    return DataPoint(arch=arch, shape="s1", mesh="m",
                     point={"remat": "full", "seq": key, "__key__": key},
                     status=status,
                     metrics={"bound_s": bound, "fits_hbm": status == "ok",
                              "hbm_bytes": hbm * 1e9, "per_device_gib": 0.5,
                              "mfu_at_bound": mfu / 10.0},
                     ts=ts)


def _row_strategy():
    """(shard, arch, key, ts, bound-mantissa, hbm-GB, mfu-decile, pruned):
    small pools force cross-shard identity collisions (steals) and the
    bound/hbm/mfu axes trade off independently, so generated cells carry
    real multi-point fronts, not a single scalar winner."""
    return st.tuples(st.integers(0, 2), st.sampled_from(ARCHS),
                     st.sampled_from(KEYS),
                     st.integers(0, 5),   # coarse ts: forces ties
                     st.integers(1, 9),   # bound mantissa
                     st.integers(1, 9),   # hbm GB
                     st.integers(1, 9),   # mfu decile
                     st.booleans())       # pruned row?


def _build_shards(tmp, rows):
    shard_dirs = [tmp / f"shard{i}" for i in range(3)]
    dbs = {i: CostDB(sd / "cost_db.jsonl") for i, sd in enumerate(shard_dirs)}
    for sd in shard_dirs:
        (sd / "reports").mkdir(parents=True, exist_ok=True)
        (sd / "dryrun_cache").mkdir(parents=True, exist_ok=True)
    cells = set()
    for shard, arch, key, ts, bound, hbm, mfu, pruned in rows:
        status = "pruned" if pruned else "ok"
        dbs[shard].append(_dp(arch, key, float(ts), bound / 10.0, hbm, mfu,
                              status))
        cells.add((shard, arch))
    for shard, arch in cells:
        (shard_dirs[shard] / "reports" / f"{arch}__s1__m.json"
         ).write_text(json.dumps({"arch": arch, "shape": "s1",
                                  "status": "complete", "improvement": 0.9}))
    return shard_dirs


def _merge_bytes(shard_dirs, out: Path):
    merge(shard_dirs, out, verbose=False, objective="pareto")
    return ((out / "cost_db.jsonl").read_bytes(),
            (out / "leaderboard.json").read_bytes())


@settings(max_examples=20, deadline=None)
@given(rows=st.lists(_row_strategy(), min_size=1, max_size=24))
def test_pareto_merge_is_order_invariant_and_idempotent(tmp_path_factory,
                                                        rows):
    """Every permutation of the shard list merges to byte-identical Pareto
    leaderboards, and re-merging the merged dir is a fixed point."""
    tmp = tmp_path_factory.mktemp("paretoprop")
    shard_dirs = _build_shards(tmp, rows)

    results = []
    for i, perm in enumerate(itertools.permutations(shard_dirs)):
        results.append(_merge_bytes(list(perm), tmp / f"out{i}"))
    assert all(r == results[0] for r in results[1:]), \
        "pareto merge output depends on shard order"

    again = _merge_bytes([tmp / "out0"], tmp / "re")
    assert again == results[0], "re-merging a merged dir changed the front"

    # and the pareto leaderboard is well-formed strict JSON
    lb = json.loads(results[0][1])
    for row in lb:
        assert row["objective"] == "pareto"
        assert row["front_size"] == len(row["front"])


@settings(max_examples=20, deadline=None)
@given(rows=st.lists(_row_strategy(), min_size=1, max_size=24))
def test_merged_front_never_contains_a_dominated_row(tmp_path_factory, rows):
    """No design in any merged front may be dominated by another surviving
    design of its cell — checked against the merged DB's own objective
    vectors, whatever the insertion order was."""
    tmp = tmp_path_factory.mktemp("paretodom")
    shard_dirs = _build_shards(tmp, rows)
    out = tmp / "out"
    _merge_bytes(shard_dirs, out)
    db = CostDB(out / "cost_db.jsonl")
    lb = json.loads((out / "leaderboard.json").read_text())
    for row in lb:
        ranked = pareto_rows(db.query(row["arch"], row["shape"], "ok",
                                      row["mesh"]))
        vec = {d.point["__key__"]:
               tuple(objectives_of(d).get(k, float("inf"))
                     * (-1.0 if k == "flops_util" else 1.0)
                     for k in ("bound_s", "hbm_bytes", "vmem_bytes",
                               "flops_util"))
               for d, _, _, _ in ranked}
        front_keys = {e["point"]["seq"] for e in row["front"]}
        assert front_keys == {d.point["__key__"]
                              for d, r, _, _ in ranked if r == 0}
        for fk in front_keys:
            for other in vec:
                assert not dominates(vec[other], vec[fk]), \
                    f"{other} dominates front member {fk} in {row['arch']}"


def test_scalar_and_pareto_merges_share_the_cost_db(tmp_path):
    """Objective mode changes only the leaderboard: the merged cost DB
    bytes are identical whether the rebuild ranks scalar heads or
    dominance fronts."""
    shard_dirs = _build_shards(tmp_path, [
        (0, "a1", "k1", 1, 2, 9, 9, False),
        (1, "a1", "k2", 2, 4, 1, 3, False),
        (2, "a1", "k3", 3, 6, 2, 1, False),
    ])
    merge(shard_dirs, tmp_path / "scalar", verbose=False)
    merge(shard_dirs, tmp_path / "pareto", verbose=False,
          objective="pareto")
    assert (tmp_path / "scalar" / "cost_db.jsonl").read_bytes() == \
        (tmp_path / "pareto" / "cost_db.jsonl").read_bytes()
    scalar = json.loads((tmp_path / "scalar" / "leaderboard.json").read_text())
    pareto = json.loads((tmp_path / "pareto" / "leaderboard.json").read_text())
    assert "front" not in scalar[0] and "objective" not in scalar[0]
    # k1 is fastest but hbm-hungry; k2 trades speed for memory: both front
    assert {e["point"]["seq"] for e in pareto[0]["front"]} == {"k1", "k2"}
    # scalar mode and pareto mode agree on the cells and the scalar stats
    assert [r["arch"] for r in scalar] == [r["arch"] for r in pareto]
