"""Property tests for ``merge_db``: the merge is a *function of the shard
contents*, not of how you call it. For generated shard DBs with overlapping
``(arch, shape, mesh, __key__)`` rows (the exact overlap a queue-mode steal
produces), any merge order must yield byte-identical cost DBs and
leaderboards, earliest-wins dedupe must hold, and re-merging a merged dir
must be a fixed point. Pure file manipulation — no jax, no subprocesses."""
import itertools
import json
from pathlib import Path

from _hypothesis_compat import given, settings, strategies as st
from repro.core.cost_db import CostDB, DataPoint
from repro.launch.merge_db import merge, merge_cost_dbs

ARCHS = ["a1", "a2"]
SHAPES = ["s1", "s2"]
KEYS = ["k1", "k2", "k3"]


def _dp(arch, shape, key, ts, bound, status="ok"):
    return DataPoint(arch=arch, shape=shape, mesh="m",
                     point={"remat": "full", "__key__": key}, status=status,
                     metrics={"bound_s": bound, "fits_hbm": status == "ok"},
                     ts=ts)


def _row_strategy():
    """One generated DB row: (shard, arch, shape, key, ts, bound, pruned).
    Small pools on purpose — collisions across shards are the interesting
    case, including *equal-timestamp* conflicting duplicates (the same ts
    and identity, different measured bound), which input-order-dependent
    tie-breaking would merge differently per permutation."""
    return st.tuples(st.integers(0, 2),              # shard index
                     st.sampled_from(ARCHS), st.sampled_from(SHAPES),
                     st.sampled_from(KEYS),
                     st.integers(0, 5),              # coarse ts: forces ties
                     st.integers(1, 9),              # bound mantissa
                     st.booleans())                  # pruned row?


def _build_shards(tmp, rows):
    """Materialize generated rows into 3 shard dirs (DB + a report per cell
    seen, so the leaderboard covers every generated cell)."""
    shard_dirs = [tmp / f"shard{i}" for i in range(3)]
    dbs = {i: CostDB(sd / "cost_db.jsonl") for i, sd in enumerate(shard_dirs)}
    for sd in shard_dirs:
        (sd / "reports").mkdir(parents=True, exist_ok=True)
        (sd / "dryrun_cache").mkdir(parents=True, exist_ok=True)
    cells = set()
    for shard, arch, shape, key, ts, bound, pruned in rows:
        status = "pruned" if pruned else "ok"
        dbs[shard].append(_dp(arch, shape, key, float(ts),
                              bound / 10.0, status))
        cells.add((shard, arch, shape))
    for shard, arch, shape in cells:
        # identical report content for a cell wherever it was "run": what a
        # deterministic re-run of a stolen cell produces on the other shard
        (shard_dirs[shard] / "reports" / f"{arch}__{shape}__m.json"
         ).write_text(json.dumps({"arch": arch, "shape": shape,
                                  "status": "complete", "improvement": 0.9}))
    return shard_dirs


def _merge_bytes(shard_dirs, out: Path):
    merge(shard_dirs, out, verbose=False)
    return ((out / "cost_db.jsonl").read_bytes(),
            (out / "leaderboard.json").read_bytes())


@settings(max_examples=20, deadline=None)
@given(rows=st.lists(_row_strategy(), min_size=1, max_size=24))
def test_merge_is_order_invariant_and_idempotent(tmp_path_factory, rows):
    """Every permutation of the shard list merges to byte-identical DB and
    leaderboard files, and merging the merged dir again is a no-op."""
    tmp = tmp_path_factory.mktemp("mergeprop")
    shard_dirs = _build_shards(tmp, rows)

    results = []
    for i, perm in enumerate(itertools.permutations(shard_dirs)):
        results.append(_merge_bytes(list(perm), tmp / f"out{i}"))
    assert all(r == results[0] for r in results[1:]), \
        "merge output depends on shard order"

    # idempotence: merge(merge(x)) == merge(x), byte for byte
    again = _merge_bytes([tmp / "out0"], tmp / "re")
    assert again == results[0], "re-merging a merged dir changed it"


@settings(max_examples=20, deadline=None)
@given(rows=st.lists(_row_strategy(), min_size=1, max_size=24))
def test_merge_dedupes_earliest_per_identity(tmp_path_factory, rows):
    """Exactly one surviving row per ``(arch, shape, mesh, key, status)``
    identity, and it is one of minimum timestamp for that identity."""
    tmp = tmp_path_factory.mktemp("mergededup")
    shard_dirs = _build_shards(tmp, rows)
    out = tmp / "out"
    kept, dropped = merge_cost_dbs(
        [sd / "cost_db.jsonl" for sd in shard_dirs], out / "cost_db.jsonl")

    merged = CostDB(out / "cost_db.jsonl").all()
    assert len(merged) == kept and kept + dropped == len(rows)

    def ident(d):
        return (d.arch, d.shape, d.mesh, d.point["__key__"], d.status)

    seen = {}
    for d in merged:
        assert ident(d) not in seen, f"duplicate identity {ident(d)}"
        seen[ident(d)] = d
    # earliest-wins: the survivor's ts is the minimum over all generated
    # rows sharing its identity
    all_ts = {}
    for shard, arch, shape, key, ts, bound, pruned in rows:
        status = "pruned" if pruned else "ok"
        all_ts.setdefault((arch, shape, "m", key, status),
                          []).append(float(ts))
    for k, d in seen.items():
        assert d.ts == min(all_ts[k]), (k, d.ts, all_ts[k])
    # and the merged stream reads chronologically
    assert [d.ts for d in merged] == sorted(d.ts for d in merged)


def test_equal_ts_conflict_merges_identically_both_orders(tmp_path):
    """The regression the order-invariance property guards: two shards
    carrying the *same identity at the same timestamp* with different
    payloads (clock granularity during a steal race) must merge the same
    whichever shard is listed first."""
    a, b = tmp_path / "a", tmp_path / "b"
    CostDB(a / "cost_db.jsonl").append(_dp("a1", "s1", "k1", 100.0, 0.5))
    CostDB(b / "cost_db.jsonl").append(_dp("a1", "s1", "k1", 100.0, 0.7))
    out1, out2 = tmp_path / "o1", tmp_path / "o2"
    merge_cost_dbs([a / "cost_db.jsonl", b / "cost_db.jsonl"],
                   out1 / "cost_db.jsonl")
    merge_cost_dbs([b / "cost_db.jsonl", a / "cost_db.jsonl"],
                   out2 / "cost_db.jsonl")
    b1 = (out1 / "cost_db.jsonl").read_bytes()
    assert b1 == (out2 / "cost_db.jsonl").read_bytes()
    rows = [DataPoint.from_json(ln) for ln in b1.decode().splitlines()]
    assert len(rows) == 1  # deduped to the content-order winner
