"""Search-strategy subsystem: protocol, the four strategies, the budget
ensemble, the surrogate gate, and the cost-DB key index they lean on."""
import numpy as np
import pytest

from repro.configs import SHAPES, SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint, featurize, workload_features
from repro.core.design_space import PlanPoint, PlanTemplate, baseline_point
from repro.search import (Candidate, Ensemble, Evolutionary,
                          GreedyNeighborhood, SearchState, SimulatedAnnealing,
                          STRATEGIES, SurrogateGate, make_strategy)
from repro.search.base import point_of, rank_candidates, select_candidates

MESH = {"data": 16, "model": 16}
ARCH, SHAPE = "llama3-8b", "train_4k"


def _template():
    return PlanTemplate(get_config(ARCH), SHAPE_BY_NAME[SHAPE], MESH)


def _dp(bound=1.0, status="ok", source="expert", **dims) -> DataPoint:
    cfg, cell = get_config(ARCH), SHAPE_BY_NAME[SHAPE]
    t = _template()
    p = PlanPoint(dims={**baseline_point(cell, t).dims, **dims})
    return DataPoint(arch=ARCH, shape=SHAPE, mesh="m",
                     point={**p.dims, "__key__": p.key()}, status=status,
                     source=source,
                     metrics={"workload": workload_features(cfg, cell),
                              "bound_s": bound, "fits_hbm": status == "ok",
                              "dominant": "collective"})


def _state(db, incumbent, budget=3, iteration=1, cost_model=None) -> SearchState:
    cfg, cell = get_config(ARCH), SHAPE_BY_NAME[SHAPE]
    return SearchState(arch=ARCH, shape=SHAPE, cfg=cfg, cell=cell,
                       template=_template(), db=db, iteration=iteration,
                       budget=budget, incumbent=incumbent,
                       pool=[incumbent] if incumbent else [],
                       cost_model=cost_model,
                       workload=workload_features(cfg, cell))


# ---------------------------------------------------------------------------
# registry + protocol
# ---------------------------------------------------------------------------
def test_registry_builds_every_strategy():
    class _Stack:  # llm strategies only need .propose at call time
        pass

    assert set(STRATEGIES) == {"greedy", "llm", "anneal", "evolve",
                               "transfer", "ensemble", "ensemble+transfer"}
    # the CLI-side literal (kept separate so --help never imports jax) must
    # track the registry exactly, or a strategy becomes CLI-unreachable /
    # fails only at the first cell of an already-spawned campaign
    from repro.launch.campaign import STRATEGY_CHOICES

    assert set(STRATEGY_CHOICES) == set(STRATEGIES)
    for name in STRATEGIES:
        s = make_strategy(name, llm_stack=_Stack())
        assert hasattr(s, "propose") and hasattr(s, "observe") and s.name

    with pytest.raises(ValueError):
        make_strategy("nope")
    with pytest.raises(ValueError):
        make_strategy("llm")  # needs llm_stack


# ---------------------------------------------------------------------------
# greedy: the extracted Explorer policy
# ---------------------------------------------------------------------------
def test_greedy_proposes_neighborhood_plus_randoms(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    inc = _dp()
    cands = GreedyNeighborhood().propose(_state(db, inc))
    assert len(cands) > 10
    assert all(c.source == "search:greedy" for c in cands)
    t = _template()
    inc_pt = point_of(inc)
    n_single = sum(
        1 for c in cands
        if sum(c.point.dims.get(k) != inc_pt.dims.get(k)
               for k in c.point.dims) == 1)
    assert n_single >= 10  # the single-dimension permutation set is in there
    for c in cands[:-1]:  # all neighbors legal (the random tail is repaired)
        ok, why = t.validate(c.point)
        assert ok, why


# ---------------------------------------------------------------------------
# simulated annealing
# ---------------------------------------------------------------------------
def test_annealing_accepts_better_and_cools(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    inc = _dp(bound=4.0)
    sa = SimulatedAnnealing(seed=3)
    t0 = sa.temperature
    cands = sa.propose(_state(db, inc, budget=3))
    assert len(cands) == 3
    assert all(c.source == "search:anneal" for c in cands)
    t = _template()
    for c in cands:
        ok, why = t.validate(c.point)
        assert ok, why

    # a strictly better evaluated candidate is always adopted as the walker
    better = cands[0].point
    dp = DataPoint(arch=ARCH, shape=SHAPE, mesh="m",
                   point={**better.dims, "__key__": better.key()}, status="ok",
                   metrics={"bound_s": 2.0, "workload": {}})
    sa.observe([dp])
    assert sa._current[0].dims == dict(better.dims)
    assert sa._current[1] == 2.0
    assert sa.temperature < t0  # geometric cooling

    # deterministic: same seed, same state -> same proposals
    sa2 = SimulatedAnnealing(seed=3)
    cands2 = sa2.propose(_state(db, inc, budget=3))
    assert [c.point.key() for c in cands2] == [c.point.key() for c in cands]


def test_annealing_radius_shrinks_when_cold(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    inc = _dp(bound=4.0)
    sa = SimulatedAnnealing(seed=0)
    for _ in range(30):  # cool to t_min
        sa.observe([])
    cands = sa.propose(_state(db, inc, budget=6, iteration=9))
    inc_pt = point_of(inc)
    for c in cands:  # cold walker = (near-)single-dimension moves
        n_changed = sum(c.point.dims.get(k) != inc_pt.dims.get(k)
                        for k in c.point.dims)
        assert n_changed <= 2  # 1 mutation + possible microbatch repair


# ---------------------------------------------------------------------------
# evolutionary
# ---------------------------------------------------------------------------
def test_evolutionary_crossover_recombines_parents(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    ev = Evolutionary(seed=1, p_mutate=0.0)  # pure crossover
    parents = [_dp(bound=1.0, remat="dots"), _dp(bound=2.0, microbatches=2)]
    ev.observe(parents)
    assert len(ev.population()) == 2
    cands = ev.propose(_state(db, parents[0], budget=5))
    assert len(cands) == 5
    assert all(c.source == "search:evolve" for c in cands)
    t = _template()
    parent_dims = [dict(point_of(p).dims) for p in parents]
    for c in cands:
        ok, why = t.validate(c.point)
        assert ok, why
        for k, v in c.point.dims.items():
            if k == "microbatches":  # repair may reset it
                continue
            assert any(v == pd.get(k) for pd in parent_dims), (k, v)


def test_evolutionary_seeds_population_from_db(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp(bound=1.5, remat="none"))
    db.append(_dp(bound=9.0, status="infeasible"))  # negatives excluded
    ev = Evolutionary(seed=0)
    ev.propose(_state(db, None, budget=1))
    assert len(ev.population()) == 1  # only the feasible row joined the pool


# ---------------------------------------------------------------------------
# ensemble: budget split + bandit credit
# ---------------------------------------------------------------------------
class _Stub:
    def __init__(self, name, points):
        self.name = name
        self._points = points
        self.observed = []

    def propose(self, state):
        return [Candidate(p, f"search:{self.name}")
                for p in self._points[: state.budget]]

    def observe(self, dps):
        self.observed.append(list(dps))


def test_ensemble_splits_budget_and_tags_sources(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    t = _template()
    pts = t.random_points(__import__("random").Random(0), 8)
    a, b = _Stub("a", pts[:4]), _Stub("b", pts[4:])
    ens = Ensemble([a, b])
    cands = ens.propose(_state(db, _dp(), budget=4))
    assert len(cands) == 4
    srcs = {c.source for c in cands}
    assert srcs == {"search:a", "search:b"}  # both members got slots


def test_ensemble_credit_follows_winning_source(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    ens = Ensemble([_Stub("a", []), _Stub("b", [])])
    # b's candidates keep improving the best-seen bound; a's never do
    ens.observe([_dp(bound=4.0, source="search:a")])  # first sets best_seen
    ens.observe([_dp(bound=3.0, source="search:b")])
    ens.observe([_dp(bound=2.0, source="search:b"),
                 _dp(bound=5.0, source="search:a")])
    assert ens.credit["b"] > ens.credit["a"]
    alloc = ens.allocation(10)
    assert alloc["b"] > alloc["a"]
    assert sum(alloc.values()) == 10
    assert min(alloc.values()) >= 1  # exploration floor
    # members saw every observation (they filter for themselves)
    assert len(ens.members[0].observed) == 3


# ---------------------------------------------------------------------------
# surrogate gate
# ---------------------------------------------------------------------------
class _StubModel:
    """Predicts a constant log10 bound; calibration report is injectable —
    optionally per-cell via ``cells={(arch, shape): (rmse, n)}`` (the global
    report answers when no cell filter, or an unknown cell, is given)."""

    def __init__(self, log_bound, rmse=0.1, n=10, trained=True, cells=None):
        self.trained = trained
        self._log_bound, self._rmse, self._n = log_bound, rmse, n
        self._cells = cells or {}

    def validation_error(self, db, arch=None, shape=None, mesh=None):
        if arch is not None and (arch, shape) in self._cells:
            return self._cells[(arch, shape)]
        return self._rmse, self._n

    def predict(self, feats):
        k = feats.shape[0]
        return np.full(k, self._log_bound), np.full(k, 0.9)


def test_gate_calibration_guard(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    good = SurrogateGate(_StubModel(2.0, rmse=0.1, n=10), max_val_rmse=0.35)
    assert good.calibrate(db) and good.active

    bad_rmse = SurrogateGate(_StubModel(2.0, rmse=1.5, n=10), max_val_rmse=0.35)
    assert not bad_rmse.calibrate(db)

    too_few = SurrogateGate(_StubModel(2.0, rmse=0.1, n=1), min_val_points=4)
    assert not too_few.calibrate(db)

    untrained = SurrogateGate(_StubModel(2.0, trained=False),
                              require_calibration=False)
    assert not untrained.calibrate(db)  # never active without a trained model

    forced = SurrogateGate(_StubModel(2.0, rmse=99.0, n=0),
                           require_calibration=False)
    assert forced.calibrate(db)  # benchmarks-only bypass

    # inactive gate passes everything through
    verdicts = bad_rmse.prune_verdicts([PlanPoint(dims={})], {}, 1.0)
    assert verdicts == [None]


def test_gate_prunes_hopeless_predictions(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    cell, t = SHAPE_BY_NAME[SHAPE], _template()
    wl = workload_features(get_config(ARCH), cell)
    pts = [baseline_point(cell, t)] + t.random_points(
        __import__("random").Random(1), 2)
    # predicts 100s for everything; incumbent at 1s, factor 4 -> all pruned
    gate = SurrogateGate(_StubModel(2.0), factor=4.0)
    gate.calibrate(db)
    verdicts = gate.prune_verdicts(pts, wl, 1.0)
    assert all(v is not None for v in verdicts)
    assert all(abs(v[0] - 100.0) < 1e-6 for v in verdicts)
    assert gate.pruned_total == len(pts)
    # same predictions but a slow incumbent -> everything passes
    assert gate.prune_verdicts(pts, wl, 50.0) == [None] * len(pts)
    # no incumbent yet -> gate stands down
    assert gate.prune_verdicts(pts, wl, None) == [None] * len(pts)


def test_gate_calibrates_per_cell_when_data_allows(tmp_path):
    """A surrogate sharp on one cell and useless globally must gate that
    cell (and only that cell); a data-poor cell falls back to the global
    validation split (skipped via the cheap key-index pre-check, without
    a full cell scan). ``last_scope`` records which split decided."""
    db = CostDB(tmp_path / "db.jsonl")
    # a1/s holds enough measured designs to justify a cell-local look,
    # a2/s doesn't (the pre-check consults the real key index)
    for arch, n_rows in (("a1", 6), ("a2", 2)):
        db.append_many([
            DataPoint(arch=arch, shape="s", mesh="m",
                      point={"__key__": f"{arch}-k{i}"}, status="ok",
                      metrics={"bound_s": 1.0, "fits_hbm": True})
            for i in range(n_rows)])
    stub = _StubModel(2.0, rmse=1.5, n=50,  # hopeless globally
                      cells={("a1", "s"): (0.1, 10),   # sharp, enough rows
                             ("a2", "s"): (0.05, 2)})  # sharp, too few rows
    gate = SurrogateGate(stub, max_val_rmse=0.35, min_val_points=4)
    assert gate.calibrate(db, arch="a1", shape="s", mesh="m")
    assert gate.last_scope == "cell" and gate.last_rmse == 0.1
    # too few cell rows -> global split guards -> stays disabled
    assert not gate.calibrate(db, arch="a2", shape="s", mesh="m")
    assert gate.last_scope == "global" and gate.last_rmse == 1.5
    # no cell context at all -> global (legacy behavior)
    assert not gate.calibrate(db)
    assert gate.last_scope == "global"


def test_gate_factor_anneals_with_calibration(tmp_path):
    """With min_factor set, the prune threshold tightens linearly from
    ``factor`` (RMSE at the guard) to ``min_factor`` (RMSE 0); without it,
    or while inactive, the configured factor stays in force."""
    db = CostDB(tmp_path / "db.jsonl")

    def gate_at(rmse, **kw):
        g = SurrogateGate(_StubModel(2.0, rmse=rmse, n=10), factor=4.0,
                          min_factor=2.0, max_val_rmse=0.35, **kw)
        g.calibrate(db)
        return g

    assert gate_at(0.35).effective_factor == pytest.approx(4.0)  # at guard
    assert gate_at(0.0).effective_factor == pytest.approx(2.0)   # perfect
    assert gate_at(0.175).effective_factor == pytest.approx(3.0)  # midpoint
    inactive = gate_at(1.5)  # fails the guard -> factor untouched
    assert not inactive.active and inactive.effective_factor == 4.0
    no_anneal = SurrogateGate(_StubModel(2.0, rmse=0.0, n=10), factor=4.0)
    no_anneal.calibrate(db)
    assert no_anneal.effective_factor == 4.0
    # the guard bypass (benchmarks) still anneals off measurable RMSE
    bypass = SurrogateGate(_StubModel(2.0, rmse=0.0, n=2), factor=4.0,
                           min_factor=2.0, require_calibration=False)
    assert bypass.calibrate(db)
    assert bypass.effective_factor == pytest.approx(2.0)
    # ... but an unmeasurable RMSE (no val rows) leaves the factor alone
    nan_rmse = SurrogateGate(_StubModel(2.0, rmse=float("nan"), n=0),
                             factor=4.0, min_factor=2.0,
                             require_calibration=False)
    assert nan_rmse.calibrate(db) and nan_rmse.effective_factor == 4.0

    # the annealed factor is the one the verdicts use: predicted 100s,
    # incumbent 30s -> 100 > 2x30 prunes, but would pass the 4x gate
    g = gate_at(0.0)
    cell, t = SHAPE_BY_NAME[SHAPE], _template()
    wl = workload_features(get_config(ARCH), cell)
    pts = [baseline_point(cell, t)]
    assert g.prune_verdicts(pts, wl, 30.0) != [None]
    loose = gate_at(0.35)
    assert loose.prune_verdicts(pts, wl, 30.0) == [None]

    with pytest.raises(ValueError):
        SurrogateGate(_StubModel(2.0), factor=4.0, min_factor=0.5)
    with pytest.raises(ValueError):
        SurrogateGate(_StubModel(2.0), factor=4.0, min_factor=5.0)


def test_training_set_cell_filter(tmp_path):
    """CostDB.training_set(arch=..., shape=...) restricts to one cell's
    rows — the data source for the gate's per-cell validation error."""
    db = CostDB(tmp_path / "db.jsonl")
    db.append_many([_dp(bound=1.0 + i, key_suffix=i) for i in range(4)])
    other = _dp(bound=9.0)
    other.arch = "other-arch"
    db.append(other)
    X_all, y_all, _ = db.training_set()
    X_cell, y_cell, _ = db.training_set(arch=ARCH, shape=SHAPE)
    X_other, _, _ = db.training_set(arch="other-arch")
    assert X_all.shape[0] == 5 and X_cell.shape[0] == 4
    assert X_other.shape[0] == 1
    assert db.training_set(arch="nope")[0].shape[0] == 0


def test_gated_evaluate_batch_records_pruned_without_compiling(tmp_path, single_mesh):
    from repro.core.evaluator import Evaluator

    db = CostDB(tmp_path / "db.jsonl")
    cell, t = SHAPE_BY_NAME[SHAPE], PlanTemplate(
        get_config(ARCH), SHAPE_BY_NAME[SHAPE], {"data": 1, "model": 1})
    pts = [baseline_point(cell, t),
           PlanPoint(dims={**baseline_point(cell, t).dims, "remat": "dots"})]
    gate = SurrogateGate(_StubModel(2.0), factor=2.0)
    gate.calibrate(db)
    ev = Evaluator(single_mesh, "m1x1")
    dps = ev.evaluate_batch(ARCH, SHAPE, pts, source=["search:a", "search:b"],
                            iteration=3, gate=gate, incumbent_bound=1.0)
    assert [d.status for d in dps] == ["pruned", "pruned"]
    assert ev.compile_count == 0 and ev.pruned_count == 2
    assert [d.source for d in dps] == ["search:a", "search:b"]  # per-point
    for d in dps:
        assert d.metrics["predicted_bound_s"] == pytest.approx(100.0)
        assert d.metrics["workload"]  # RAG featurization still possible
        assert "surrogate gate" in d.reason
    # pruned rows are recorded in the DB but never become training targets
    db.append_many(dps)
    X, y, feas = db.training_set()
    assert X.shape[0] == 0


def test_evaluate_batch_rejects_mismatched_sources(single_mesh):
    from repro.core.evaluator import Evaluator

    cell = SHAPE_BY_NAME[SHAPE]
    t = PlanTemplate(get_config(ARCH), cell, {"data": 1, "model": 1})
    with pytest.raises(ValueError):
        Evaluator(single_mesh, "m1x1").evaluate_batch(
            ARCH, SHAPE, [baseline_point(cell, t)], source=["a", "b"])


# ---------------------------------------------------------------------------
# cost-DB key index (the dedupe satellite) + held-out split
# ---------------------------------------------------------------------------
def test_costdb_key_index_stays_current(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    assert db.keys(ARCH, SHAPE) == set()
    d1, d2 = _dp(remat="dots"), _dp(microbatches=2)
    db.append_many([d1, d2])
    expect = {d1.point["__key__"], d2.point["__key__"]}
    assert db.keys(ARCH, SHAPE) == expect
    assert db.seen(ARCH, SHAPE, d1.point["__key__"])
    assert not db.seen(ARCH, SHAPE, "nope")
    # appends after the index is built keep it current (no rescan)
    d3 = _dp(zero1=False)
    db.append(d3)
    assert d3.point["__key__"] in db.keys(ARCH, SHAPE)
    # a fresh handle over the same file rebuilds the same index from disk
    db2 = CostDB(tmp_path / "db.jsonl")
    assert db2.keys(ARCH, SHAPE) == expect | {d3.point["__key__"]}
    assert db2.keys("other-arch", SHAPE) == set()


def test_costdb_pruned_keys_stay_proposable(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    measured = _dp(remat="dots")
    pruned = _dp(microbatches=2, status="pruned")
    db.append_many([measured, pruned])
    pk = pruned.point["__key__"]
    assert pk in db.keys(ARCH, SHAPE)  # recorded...
    assert pk not in db.keys(ARCH, SHAPE, include_pruned=False)  # ...not measured
    # select_candidates re-admits the pruned design but not the measured one
    cands = [Candidate(point_of(measured), "x"), Candidate(point_of(pruned), "x")]
    sel = select_candidates(_state(db, None), cands)
    assert [c.point.key() for c in sel] == [pk]
    # once actually evaluated, the measured status wins and sticks
    db.append(_dp(microbatches=2, status="ok"))
    assert pk in db.keys(ARCH, SHAPE, include_pruned=False)
    # ...including when the index is rebuilt from disk in any row order
    db2 = CostDB(tmp_path / "db.jsonl")
    assert pk in db2.keys(ARCH, SHAPE, include_pruned=False)


def test_training_set_split_partitions_rows(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    for mb in (1, 2, 4, 8):
        for lc in (0, 512, 1024):
            for z in (True, False):
                db.append(_dp(bound=10.0 / mb, microbatches=mb,
                              loss_chunk=lc, zero1=z))
    X_all, _, _ = db.training_set()
    X_tr, _, _ = db.training_set(split="train")
    X_val, _, _ = db.training_set(split="val")
    assert X_tr.shape[0] + X_val.shape[0] == X_all.shape[0] == 24
    assert X_val.shape[0] > 0, "deterministic hash split left val empty"
    # deterministic: same DB, same partition
    X_val2, _, _ = CostDB(tmp_path / "db.jsonl").training_set(split="val")
    assert X_val.shape == X_val2.shape


def test_rank_candidates_insertion_order_without_model(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    t = _template()
    cands = [Candidate(p, "x") for p in
             t.random_points(__import__("random").Random(2), 4)]
    assert rank_candidates(_state(db, None), cands) == cands


# ---------------------------------------------------------------------------
# soak: annealing + evolutionary drive the full loop end-to-end (excluded
# from fast runs via the `slow` marker: real dry-run compiles)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_annealing_and_evolutionary_loops_end_to_end(tmp_path):
    from conftest import run_subprocess
    from test_campaign_engine import TINY_PRELUDE

    out = run_subprocess(f"""{TINY_PRELUDE}
        from repro.core.cost_db import CostDB
        from repro.core.llm_client import MockLLM
        from repro.core.llm_stack import LLMStack
        from repro.core.loop import DSELoop
        from repro.search import make_strategy

        for name in ("anneal", "evolve"):
            db = CostDB(rf"{tmp_path}/db_{{name}}.jsonl")
            loop = DSELoop(
                evaluator=Evaluator(mesh, "tiny1x1",
                                    artifact_dir=rf"{tmp_path}/{{name}}",
                                    cache=DryRunCache(rf"{tmp_path}/c_{{name}}")),
                db=db, llm_stack=LLMStack(client=MockLLM(), db=db),
                strategy=make_strategy(name))
            report = loop.run("qwen3-0.6b", "train_4k", iterations=2,
                              eval_budget=2, verbose=False)
            assert report.baseline is not None and report.baseline.status == "ok"
            assert report.best is not None and report.improvement() <= 1.001
            srcs = {{d.source for d in db.all()}}
            assert f"search:{{name}}" in srcs, srcs
            assert len(db.all()) >= 3, len(db.all())
            print("SOAK_OK", name, report.improvement())
    """, n_devices=1, timeout=900)
    assert "SOAK_OK anneal" in out and "SOAK_OK evolve" in out
