"""Optional-`hypothesis` shim for the property-style tests.

When `hypothesis` is installed the real library is re-exported unchanged.
When it is not, a minimal deterministic stand-in runs each `@given` test
against `max_examples` seeded pseudo-random draws (seeded from the test's
qualified name, so every run sweeps the same examples). Only the strategy
surface this suite uses is implemented: integers, sampled_from, booleans,
lists, tuples.

Usage in test modules (replaces `from hypothesis import ...`):

    from _hypothesis_compat import given, settings, strategies as st
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ModuleNotFoundError:
    import inspect
    import random
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda rng: tuple(e.example(rng) for e in elements))

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # hide the drawn params from pytest so it doesn't look for fixtures
            orig = inspect.signature(fn)
            wrapper.__signature__ = inspect.Signature(
                [p for name, p in orig.parameters.items() if name not in strats])
            return wrapper

        return deco
