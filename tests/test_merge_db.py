"""Sharded campaigns: deterministic grid partition, DB/report/cache merge,
and the merged leaderboard reproducing a single-process run byte-for-byte."""
import json
from pathlib import Path

import pytest

from conftest import run_subprocess
from repro.core.cost_db import CostDB, DataPoint
from repro.launch.campaign import shard_cells
from repro.launch.merge_db import merge, merge_cost_dbs


def _dp(arch="a1", shape="s", mesh="m", key="k1", bound=1.0, ts=100.0,
        status="ok"):
    return DataPoint(arch=arch, shape=shape, mesh=mesh,
                     point={"remat": "full", "__key__": key}, status=status,
                     metrics={"bound_s": bound, "fits_hbm": status == "ok"},
                     ts=ts)


# ---------------------------------------------------------------------------
# shard partition
# ---------------------------------------------------------------------------
def test_shard_cells_disjoint_and_exhaustive():
    archs, shapes = ["b", "a", "c"], ["s2", "s1"]
    full = shard_cells(archs, shapes)
    assert full == sorted(full) and len(full) == 6
    for n in (1, 2, 3, 4):
        parts = [shard_cells(archs, shapes, (i, n)) for i in range(n)]
        assert sorted(c for p in parts for c in p) == full
        seen = [c for p in parts for c in p]
        assert len(seen) == len(set(seen))  # disjoint
    # input order never matters
    assert shard_cells(list(reversed(archs)), shapes, (0, 2)) == \
        shard_cells(archs, shapes, (0, 2))
    with pytest.raises(ValueError):
        shard_cells(archs, shapes, (2, 2))


# ---------------------------------------------------------------------------
# DB merge: dedup by identity, earliest record wins
# ---------------------------------------------------------------------------
def test_merge_cost_dbs_dedups_earliest(tmp_path):
    db_a = CostDB(tmp_path / "a" / "cost_db.jsonl")
    db_b = CostDB(tmp_path / "b" / "cost_db.jsonl")
    db_a.append(_dp(key="k1", bound=1.0, ts=100.0))
    db_a.append(_dp(key="k2", bound=2.0, ts=300.0))
    db_b.append(_dp(key="k1", bound=9.0, ts=200.0))  # later dup: dropped
    db_b.append(_dp(key="k3", bound=3.0, ts=50.0))
    db_b.append(_dp(arch="a2", key="k1", ts=400.0))  # same key, other cell
    # a pruned prediction + its later measured outcome both survive (status
    # is part of the dedup identity, matching a single-process DB)
    db_a.append(_dp(key="k4", bound=None, ts=10.0, status="pruned"))
    db_a.append(_dp(key="k4", bound=0.5, ts=500.0))

    out = tmp_path / "out" / "cost_db.jsonl"
    kept, dropped = merge_cost_dbs([db_a.path, db_b.path], out)
    assert (kept, dropped) == (6, 1)
    rows = CostDB(out).all()
    assert [d.ts for d in rows] == sorted(d.ts for d in rows)  # chronological
    k1 = [d for d in rows if d.point["__key__"] == "k1" and d.arch == "a1"]
    assert len(k1) == 1 and k1[0].metrics["bound_s"] == 1.0  # earliest won
    k4 = [d for d in rows if d.point["__key__"] == "k4"]
    assert sorted(d.status for d in k4) == ["ok", "pruned"]
    assert CostDB(out).best("a1", "s").metrics["bound_s"] == 0.5


def test_merge_full_dirs_builds_leaderboard(tmp_path):
    for i, (arch, bound, ts) in enumerate((("a1", 2.0, 10.0),
                                           ("a2", 1.0, 20.0))):
        sd = tmp_path / f"shard{i}"
        CostDB(sd / "cost_db.jsonl").append(
            _dp(arch=arch, key=f"k{i}", bound=bound, ts=ts))
        (sd / "reports").mkdir()
        (sd / "reports" / f"{arch}__s__m.json").write_text(json.dumps(
            {"arch": arch, "shape": "s", "status": "complete",
             "improvement": 0.9}))
        (sd / "dryrun_cache").mkdir()
        (sd / "dryrun_cache" / f"e{i}.json").write_text("{}")

    out = tmp_path / "merged"
    s = merge([tmp_path / "shard0", tmp_path / "shard1"], out, verbose=False)
    assert s["datapoints"] == 2 and s["duplicates_dropped"] == 0
    assert s["reports"] == 2 and s["cache_entries_copied"] == 2
    lb = json.loads((out / "leaderboard.json").read_text())
    assert [r["arch"] for r in lb] == ["a2", "a1"]  # fastest first
    assert all(r["status"] == "complete" for r in lb)
    assert (out / "reports" / "a1__s__m.json").exists()

    with pytest.raises(FileNotFoundError):
        merge([tmp_path / "missing"], out / "x", verbose=False)
    with pytest.raises(ValueError):
        merge([tmp_path / "shard0"], tmp_path / "shard0", verbose=False)


# ---------------------------------------------------------------------------
# two-shard campaign + merge == single-process campaign, byte-for-byte
# (deterministic mock LLM; surrogate untrained at iterations=1 so ranking
# and gating cannot couple cells across shard boundaries)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_two_shard_campaign_merge_matches_single_process(tmp_path):
    from test_campaign_engine import TINY_PRELUDE

    out = run_subprocess(f"""{TINY_PRELUDE}
        import json
        from pathlib import Path
        from repro.launch.campaign import run_campaign
        from repro.launch.merge_db import merge

        grid = dict(archs=["qwen3-0.6b", "stablelm-3b"], shapes=["train_4k"])
        common = dict(mesh=mesh, mesh_name="tiny1x1", iterations=1, budget=2,
                      workers=1, verbose=False)
        s_all = run_campaign(**grid, out_dir=r"{tmp_path}/single", **common)
        assert s_all["ran"] == 2, s_all

        s0 = run_campaign(**grid, out_dir=r"{tmp_path}/shard0",
                          shard=(0, 2), **common)
        s1 = run_campaign(**grid, out_dir=r"{tmp_path}/shard1",
                          shard=(1, 2), **common)
        assert s0["ran"] == 1 and s1["ran"] == 1, (s0, s1)
        assert s0["shard"] == "0/2" and s1["shard"] == "1/2"

        m = merge([r"{tmp_path}/shard0", r"{tmp_path}/shard1"],
                  r"{tmp_path}/merged", verbose=False)
        assert m["reports"] == 2 and m["duplicates_dropped"] == 0, m

        single = Path(r"{tmp_path}/single/leaderboard.json").read_bytes()
        merged = Path(r"{tmp_path}/merged/leaderboard.json").read_bytes()
        assert single == merged, (single[:400], merged[:400])
        print("MERGE_BYTE_FOR_BYTE_OK", len(json.loads(merged)))
    """, n_devices=1, timeout=900)
    assert "MERGE_BYTE_FOR_BYTE_OK 2" in out
