"""CellQueue: the crash-safe file-backed lease queue under the work-stealing
scheduler. Unit tests for each lifecycle transition (seed / acquire / renew /
complete / steal / release / expiry-reclaim), concurrency races over the
atomic-rename claim protocol, crash-window recovery, and a property sweep
(hypothesis, or the deterministic shim) asserting the one-state-per-ticket
invariant under random operation sequences. No jax, no subprocess compiles."""
import json
import os
import threading

import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.launch.scheduler import (DONE, LEASED, PENDING, CellQueue, Ticket,
                                    sanitize_owner)

CELLS = [("a1", "s1"), ("a1", "s2"), ("a2", "s1"), ("a2", "s2")]


def make_queue(tmp_path, lease_s=60.0, cells=CELLS):
    q = CellQueue(tmp_path / "queue", lease_s=lease_s)
    q.seed(cells, mesh="tiny1x1")
    return q


# ---------------------------------------------------------------------------
# construction / seeding
# ---------------------------------------------------------------------------
def test_seed_is_idempotent_across_states(tmp_path):
    q = make_queue(tmp_path)
    assert q.counts() == {"pending": 4, "leased": 0, "done": 0}
    assert q.seed(CELLS) == 0  # already pending
    t = q.acquire("w0")
    q.complete(t)
    t2 = q.acquire("w1")
    # re-seeding resurrects neither the done nor the leased ticket
    assert q.seed(CELLS, mesh="tiny1x1") == 0
    assert q.counts() == {"pending": 2, "leased": 1, "done": 1}
    assert q.seed(CELLS + [("z9", "s9")]) == 1  # only the new cell
    q.complete(t2)


def test_ticket_roundtrip_and_identity(tmp_path):
    t = Ticket(arch="a1", shape="s1", mesh="m")
    assert Ticket.from_json(t.to_json()) == t
    assert t.cell == "a1/s1" and t.file_name == "a1__s1.json"
    assert t.duration() is None
    assert Ticket(arch="a", shape="s", leased_at=1.0, done_at=3.5
                  ).duration() == 2.5
    with pytest.raises(ValueError):
        sanitize_owner("")
    assert sanitize_owner("shard 0/2") == "shard_0_2"
    assert sanitize_owner("w0") == "w0"


def test_rejects_nonpositive_lease(tmp_path):
    with pytest.raises(ValueError):
        CellQueue(tmp_path / "q", lease_s=0)


# ---------------------------------------------------------------------------
# acquire / complete lifecycle
# ---------------------------------------------------------------------------
def test_acquire_orders_cells_and_stamps_lease(tmp_path):
    q = make_queue(tmp_path)
    t = q.acquire("w0", now=100.0)
    assert (t.arch, t.shape) == ("a1", "s1")  # sorted order, front first
    assert t.owner == "w0" and t.attempt == 1
    assert t.leased_at == 100.0 and t.deadline == 160.0
    # the lease is visible to any other queue instance over the same root
    q2 = CellQueue(q.root)
    leased = q2.tickets(LEASED)
    assert [x.cell for x in leased] == ["a1/s1"] and leased[0].owner == "w0"


def test_acquire_returns_none_when_nothing_pending(tmp_path):
    q = make_queue(tmp_path, cells=[("a1", "s1")])
    t = q.acquire("w0")
    assert q.acquire("w1") is None  # leased, not pending — and not drained
    assert not q.drained()
    assert q.complete(t)
    assert q.acquire("w1") is None
    assert q.drained()


def test_complete_records_outcome_and_duration(tmp_path):
    q = make_queue(tmp_path)
    t = q.acquire("w0", now=10.0)
    assert q.complete(t, status="complete", now=14.0)
    done = q.tickets(DONE)[0]
    assert done.status == "complete" and done.duration() == 4.0
    assert done.deadline is None
    # completing twice is a loud no (the lease is gone)
    assert not q.complete(t)


def test_counts_total_and_drained(tmp_path):
    q = make_queue(tmp_path)
    assert q.total() == 4 and not q.drained()
    while (t := q.acquire("w")) is not None:
        q.complete(t)
    assert q.drained() and q.total() == 4
    assert q.counts() == {"pending": 0, "leased": 0, "done": 4}


def test_concurrent_seeders_never_resurrect_a_claimed_cell(tmp_path):
    """Seeders that race workers (the manual cooperating-commands flow)
    must not recreate a pending ticket for a cell that is already leased
    or done: seeding is lock-serialized, per-cell existence-checked, and
    the create is an exclusive link — the one-state-per-ticket invariant
    survives seed/acquire/complete interleavings from many processes."""
    q = CellQueue(tmp_path / "queue", lease_s=60.0)
    stop = {"flag": False}
    errors = []

    def seed_loop():
        mine = CellQueue(q.root)
        try:
            while not stop["flag"]:
                mine.seed(CELLS, mesh="tiny1x1")
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    seeders = [threading.Thread(target=seed_loop) for _ in range(2)]
    for th in seeders:
        th.start()
    try:
        worker = CellQueue(q.root)
        done = 0
        while done < len(CELLS):
            t = worker.acquire("w0")
            if t is None:
                continue
            assert worker.complete(t)
            done += 1
            # the invariant, checked while seeders hammer the queue
            names = [x.file_name for x in worker.tickets()]
            assert sorted(names) == sorted(set(names)), names
    finally:
        stop["flag"] = True
        for th in seeders:
            th.join()
    assert not errors, errors
    # nothing resurrected, nothing lost: all cells done exactly once
    q.seed(CELLS)  # one more idempotent pass for good measure
    assert q.counts() == {"pending": 0, "leased": 0, "done": len(CELLS)}


def test_seed_lock_breaks_stale_holder(tmp_path):
    """A seeder that died mid-seed leaves the lock dir behind; the next
    seeder must break it once it is stale instead of deadlocking."""
    q = CellQueue(tmp_path / "queue", lease_s=60.0)
    lock = q.root / "seed.lock"
    lock.mkdir()
    os.utime(lock, (0, 0))  # ancient mtime: holder long dead
    assert q.seed(CELLS) == len(CELLS)
    assert not lock.exists()


# ---------------------------------------------------------------------------
# contention: the atomic-rename claim must hand each ticket to exactly one
# ---------------------------------------------------------------------------
def test_two_workers_never_share_a_ticket(tmp_path):
    q = make_queue(tmp_path)
    got = {"w0": [], "w1": []}

    def drain(owner):
        mine = CellQueue(q.root)  # own instance, like a separate process
        while (t := mine.acquire(owner)) is not None:
            got[owner].append(t.cell)
            mine.complete(t)

    threads = [threading.Thread(target=drain, args=(o,)) for o in got]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    claimed = got["w0"] + got["w1"]
    assert sorted(claimed) == sorted(f"{a}/{s}" for a, s in CELLS)
    assert len(claimed) == len(set(claimed))  # exactly-once
    assert q.drained()


def test_steal_vs_complete_race_is_exactly_once(tmp_path):
    """Whoever renames first wins; the loser sees the lease gone. Either
    way the ticket lands in exactly one state."""
    q = make_queue(tmp_path, cells=[("a1", "s1")])
    t = q.acquire("slow")
    assert q.complete(t)          # owner finishes first...
    assert q.steal(t) is None     # ...so the steal loses, loudly
    assert q.counts() == {"pending": 0, "leased": 0, "done": 1}

    q2 = make_queue(tmp_path / "b", cells=[("a1", "s1")])
    t2 = q2.acquire("slow")
    assert q2.steal(t2) is not None  # steal first...
    assert not q2.complete(t2)       # ...so the owner's complete loses
    assert q2.counts() == {"pending": 1, "leased": 0, "done": 0}


# ---------------------------------------------------------------------------
# stealing, releasing, expiry
# ---------------------------------------------------------------------------
def test_steal_returns_cell_to_pending_with_audit_trail(tmp_path):
    q = make_queue(tmp_path)
    t = q.acquire("slow")
    s = q.steal(t)
    assert s.steals == 1 and s.owner is None and s.leased_at is None
    re = q.acquire("fast")
    assert re.cell == t.cell and re.attempt == 2 and re.steals == 1
    assert q.complete(re)
    done = [x for x in q.tickets(DONE) if x.cell == t.cell][0]
    assert done.attempt == 2 and done.steals == 1


def test_release_owner_reclaims_only_that_owner(tmp_path):
    q = make_queue(tmp_path)
    t0 = q.acquire("w0")
    t1 = q.acquire("w1")
    released = q.release_owner("w0")
    assert [t.cell for t in released] == [t0.cell]
    assert q.counts()["leased"] == 1  # w1's lease untouched
    assert not released[0].steals  # a crash reclaim is not a steal
    assert q.complete(t1)
    assert not q.complete(t0)  # w0's lease is gone


def test_expired_lease_is_reclaimed_and_fresh_one_is_not(tmp_path):
    q = make_queue(tmp_path, lease_s=50.0)
    t = q.acquire("w0", now=100.0)  # deadline 150
    assert q.reclaim_expired(now=149.0) == []
    rec = q.reclaim_expired(now=151.0)
    assert [x.cell for x in rec] == [t.cell]
    re = q.acquire("w1", now=151.0)
    assert re.cell == t.cell and re.attempt == 2


def test_renew_pushes_deadline_and_reports_lost_lease(tmp_path):
    q = make_queue(tmp_path, lease_s=50.0)
    t = q.acquire("w0", now=100.0)
    assert q.renew(t, now=140.0)  # deadline now 190
    assert q.reclaim_expired(now=160.0) == []  # renewal kept it alive
    q.steal(t)
    assert not q.renew(t)  # lease gone: the owner learns on next beat
    # and the failed renewal must NOT have resurrected the lease file —
    # the ticket stays in exactly one state (the steal's pending)
    assert q.counts() == {"pending": 4, "leased": 0, "done": 0}
    # ...so the thief's complete wins and the old owner's loses
    re = q.acquire("fast")
    assert re.cell == t.cell
    assert not q.complete(t) and q.complete(re)


def test_owner_ids_can_never_look_like_tmp_debris(tmp_path):
    """An owner sanitizing to something containing '.tmp' would make its
    lease files invisible to every scan (drained() would lie while a cell
    is still leased); dots are therefore stripped from owner ids."""
    q = make_queue(tmp_path, cells=[("a1", "s1")])
    assert sanitize_owner("w.tmp1") == "w_tmp1"
    t = q.acquire("w.tmp1")
    assert t.owner == "w_tmp1"
    assert q.counts()["leased"] == 1 and not q.drained()
    assert [x.owner for x in q.tickets(LEASED)] == ["w_tmp1"]
    assert q.release_owner("w.tmp1")  # reclaim sees it too
    assert q.counts()["pending"] == 1


def test_acquire_reclaims_expired_leases_first(tmp_path):
    q = make_queue(tmp_path, lease_s=10.0, cells=[("a1", "s1")])
    q.acquire("dead", now=0.0)
    # nothing pending, but the dead worker's lease is expired: a late
    # acquirer gets the cell in one call
    t = q.acquire("w1", now=100.0)
    assert t is not None and t.attempt == 2


# ---------------------------------------------------------------------------
# crash windows: filename state survives even when content rewrites are lost
# ---------------------------------------------------------------------------
def test_claim_crash_window_falls_back_to_mtime(tmp_path):
    """A worker that dies between the claim-rename and the content rewrite
    leaves a leased file with pending-era content (no owner, no deadline).
    The filename still names the owner, and expiry falls back to file
    mtime + lease_s, so the ticket is reclaimed like any orphan."""
    q = make_queue(tmp_path, lease_s=30.0, cells=[("a1", "s1")])
    pend = q.root / PENDING / "a1__s1.json"
    stale = pend.read_text()
    # simulate the crash: rename happened, rewrite never did
    (q.root / LEASED / "a1__s1.json.lease-ghost").write_text(stale)
    pend.unlink()
    leased = q.tickets(LEASED)
    assert leased[0].owner == "ghost"  # recovered from the filename
    assert q.reclaim_expired(now=0.0) == []  # mtime is "now": not expired
    import time

    rec = q.reclaim_expired(now=time.time() + 31.0)
    assert [t.cell for t in rec] == ["a1/s1"]
    assert q.acquire("w1") is not None


def test_torn_ticket_files_recover_from_their_filename(tmp_path):
    q = make_queue(tmp_path)
    (q.root / PENDING / "a1__s1.json").write_text('{"arch": ')  # torn
    assert len(q.tickets()) == 3  # listings skip the unreadable one
    t = q.acquire("w0")
    # ...but acquire recovers it: the filename is the identity, so a torn
    # content write never loses a cell
    assert t.cell == "a1/s1" and t.attempt == 1
    assert q.complete(t)
    # tmp debris from atomic writes is never parsed as a ticket
    (q.root / PENDING / "a2__s9.json.tmp999").write_text("{}")
    assert len(q.tickets(PENDING)) == 3


# ---------------------------------------------------------------------------
# property sweep: one state per ticket, conserved total, monotone audit
# trail — under arbitrary operation sequences from any number of owners
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["acquire", "complete",
                                               "steal", "release",
                                               "reclaim"]),
                              st.integers(0, 2)),
                    min_size=1, max_size=40))
def test_random_op_sequences_hold_invariants(tmp_path_factory, ops):
    """Any interleaving of queue operations keeps every cell in exactly one
    state, never loses or duplicates a ticket, and only ever grows the
    attempt/steal counters."""
    tmp = tmp_path_factory.mktemp("qprop")
    q = CellQueue(tmp / "q", lease_s=1000.0)
    q.seed(CELLS)
    owners = ["w0", "w1", "w2"]
    held = {o: [] for o in owners}
    clock = [0.0]

    def check():
        c = q.counts()
        assert sum(c.values()) == len(CELLS), c
        names = [t.file_name for t in q.tickets()]
        assert sorted(names) == sorted(set(names))  # one state per cell
        for t in q.tickets():
            assert t.attempt >= 0 and t.steals >= 0

    for op, i in ops:
        clock[0] += 1.0
        o = owners[i]
        if op == "acquire":
            t = q.acquire(o, now=clock[0])
            if t is not None:
                held[o].append(t)
        elif op == "complete" and held[o]:
            q.complete(held[o].pop(), now=clock[0])
        elif op == "steal" and held[o]:
            q.steal(held[o].pop(0), now=clock[0])
        elif op == "release":
            q.release_owner(o, now=clock[0])
            held[o].clear()
        elif op == "reclaim":
            q.reclaim_expired(now=clock[0])
        check()

    # drain to done from any intermediate state: the queue always converges
    for o, ts in held.items():
        for t in ts:
            q.complete(t, now=clock[0])
    while (t := q.acquire("finisher", now=clock[0])) is not None:
        q.complete(t, now=clock[0])
    assert q.drained()
    assert q.counts() == {"pending": 0, "leased": 0, "done": len(CELLS)}
    for t in q.tickets(DONE):
        assert t.status == "complete" and t.attempt >= 1
        assert json.loads(t.to_json())["arch"] == t.arch
