"""DSE-as-a-service control plane: API lifecycle, tenant isolation,
cross-tenant coalescing, and byte-identical leaderboards.

The daemon subprocess must never import jax (``/healthz`` reports
``jax_loaded``); jax exists only in the campaign workers it spawns. The
end-to-end tests boot the real daemon with the tiny CI prelude forwarded
to its workers, so a three-tenant fleet drains in seconds.
"""
import json
import os
import subprocess
import sys
import time
import urllib.request
from contextlib import contextmanager
from pathlib import Path

import pytest

from repro.launch.scheduler import CellQueue
from repro.launch.service import (PROFILE_DEFAULTS, ServiceDaemon,
                                  SubmitError, build_parser,
                                  snapshot_tenants)

REPO = Path(__file__).resolve().parents[1]
TINY_PRELUDE_FILE = REPO / "tests" / "ci" / "tiny_prelude.py"

TENANT_GRIDS = {
    # overlapping 2-cell grids: (qwen3-0.6b, train_4k) is shared
    "alice": {"archs": "qwen3-0.6b", "shapes": "train_4k,decode_32k"},
    "bob": {"archs": "qwen3-0.6b,stablelm-3b", "shapes": "train_4k"},
}
PROFILE = {"mesh": "tiny", "iterations": 1, "budget": 2}


def _env():
    return {**os.environ, "PYTHONPATH": str(REPO / "src"),
            "REPRO_CAMPAIGN_PRELUDE": str(TINY_PRELUDE_FILE)}


def _get(url, path):
    with urllib.request.urlopen(url + path, timeout=30) as r:
        return json.loads(r.read())


def _get_bytes(url, path):
    with urllib.request.urlopen(url + path, timeout=60) as r:
        return r.read()


def _post(url, path, payload=None):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload or {}).encode(),
        method="POST", headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=60) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


@contextmanager
def service_daemon(root: Path, *extra_args, env=None):
    """Boot ``python -m repro.launch.service serve`` on a free port; yields
    the base URL; shuts the daemon down (and asserts exit 0) on the way
    out."""
    log = (root.parent / f"{root.name}.log").open("w")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.service", "serve",
         "--root", str(root), "--port", "0", "--poll-interval", "0.2",
         "--queue-lease-s", "60", *extra_args],
        env=env or _env(), stdout=log, stderr=subprocess.STDOUT)
    endpoint = root / "endpoint.json"
    try:
        deadline = time.time() + 30
        while not endpoint.exists():
            assert proc.poll() is None, "daemon died during startup"
            assert time.time() < deadline, "daemon never wrote endpoint.json"
            time.sleep(0.1)
        ep = json.loads(endpoint.read_text())
        url = f"http://{ep['host']}:{ep['port']}"
        yield url
        _post(url, "/shutdown")
        assert proc.wait(timeout=60) == 0
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
        log.close()


def _submit(url, tenant, grid, **profile):
    payload = {"tenant": tenant, "arch": grid["archs"],
               "shape": grid["shapes"], **PROFILE, **profile}
    code, body = _post(url, "/submit", payload)
    assert code == 200, body
    return body


def _wait_drained(url, tenants, timeout=420):
    deadline = time.time() + timeout
    while time.time() < deadline:
        idx = _get(url, "/tenants")["tenants"]
        done = all(
            t in idx and idx[t]["queue"]["pending"] == 0
            and idx[t]["queue"]["leased"] == 0
            and idx[t]["workers_active"] == 0 for t in tenants)
        if done:
            return idx
        time.sleep(1.0)
    raise AssertionError(f"tenants {tenants} never drained: "
                         f"{_get(url, '/tenants')}")


def _standalone_leaderboard(tmp: Path, grid, **profile) -> bytes:
    """The byte reference: an equivalent standalone campaign run."""
    p = {**PROFILE_DEFAULTS, **PROFILE, **profile}
    cmd = [sys.executable, "-m", "repro.launch.campaign",
           "--archs", grid["archs"], "--shapes", grid["shapes"],
           "--mesh", p["mesh"], "--iterations", str(p["iterations"]),
           "--budget", str(p["budget"]), "--workers", "1",
           "--strategy", p["strategy"], "--llm", p["llm"],
           "--out", str(tmp)]
    if p["objective"] != "bound_s":
        cmd += ["--objective", p["objective"]]
    r = subprocess.run(cmd, capture_output=True, text=True, env=_env(),
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    return (tmp / "leaderboard.json").read_bytes()


# ---------------------------------------------------------------------------
# CLI surface + in-process daemon logic (no subprocesses, no jax)
# ---------------------------------------------------------------------------
def test_parser_subcommands_roundtrip():
    ap = build_parser()
    a = ap.parse_args(["serve", "--root", "svc", "--port", "0",
                       "--max-workers", "3", "--executor", "loopback"])
    assert (a.command, a.max_workers, a.executor) == ("serve", 3, "loopback")
    a = ap.parse_args(["submit", "--tenant", "t0", "--archs", "qwen3-0.6b",
                       "--shapes", "train_4k", "--objective", "pareto",
                       "--priority", "3"])
    assert (a.command, a.objective, a.priority) == ("submit", "pareto", 3)
    for cmd in ("status", "shutdown"):
        assert build_parser().parse_args([cmd]).command == cmd
    a = ap.parse_args(["leaderboard", "--tenant", "t0"])
    assert a.out == "-"
    with pytest.raises(SystemExit):
        build_parser().parse_args(["serve"])  # --root is required
    with pytest.raises(SystemExit):
        build_parser().parse_args(["submit", "--tenant", "t0"])


def test_snapshot_tenants_stall_detection():
    facts = [
        {"name": "b", "priority": 2, "backlog": 3, "workers": 1,
         "worker_beats": [100.0]},
        {"name": "a", "backlog": 1, "workers": 2,
         "worker_beats": [100.0, 499.0]},
        {"name": "c", "backlog": 1},  # no workers: never stalled
    ]
    snaps = snapshot_tenants(facts, hang_timeout=300.0, now=500.0)
    assert [s.name for s in snaps] == ["a", "b", "c"]
    by = {s.name: s for s in snaps}
    assert by["b"].stalled  # its only worker is 400s silent
    assert not by["a"].stalled  # one worker still beating
    assert not by["c"].stalled
    assert by["b"].priority == 2 and by["a"].workers == 2


def test_submit_validation_and_profile_pinning(tmp_path):
    d = ServiceDaemon(tmp_path / "svc", verbose=False)
    with pytest.raises(SubmitError) as e:
        d.submit({"tenant": "../evil", "arch": "qwen3-0.6b",
                  "shape": "train_4k"})
    assert e.value.code == 400
    with pytest.raises(SubmitError) as e:
        d.submit({"tenant": "t0", "arch": "no-such-arch",
                  "shape": "train_4k"})
    assert e.value.code == 400
    with pytest.raises(SubmitError) as e:
        d.submit({"tenant": "t0", "arch": "qwen3-0.6b", "shape": "train_4k",
                  "mesh": "warehouse"})
    assert e.value.code == 400

    rec = d.submit({"tenant": "t0", "arch": "qwen3-0.6b",
                    "shape": "train_4k,decode_32k", "mesh": "tiny"})
    assert rec["id"] == 1 and rec["seeded"] == 2
    # re-submitting the same grid is idempotent at the queue level
    rec2 = d.submit({"tenant": "t0", "arch": "qwen3-0.6b",
                     "shape": "train_4k", "mesh": "tiny"})
    assert rec2["seeded"] == 0
    # the campaign profile is pinned by the first submission
    with pytest.raises(SubmitError) as e:
        d.submit({"tenant": "t0", "arch": "qwen3-0.6b", "shape": "train_4k",
                  "mesh": "tiny", "objective": "pareto"})
    assert e.value.code == 409
    status = d.tenant_status("t0")
    assert status["queue"]["pending"] == 2
    assert status["profile"]["mesh"] == "tiny"
    # both tenant cache dirs are symlinks into the shared service caches
    qroot = tmp_path / "svc" / "tenants" / "t0" / "queue"
    for cache in ("dryrun_cache", "measured_cache"):
        assert (qroot / cache).is_symlink()
        assert (qroot / cache).resolve() == (tmp_path / "svc" / cache)


# ---------------------------------------------------------------------------
# end to end: lifecycle, coalescing, byte-identical leaderboards
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def service_run(tmp_path_factory):
    """One daemon, three tenants: two scalar tenants with overlapping
    2-cell grids plus a Pareto tenant reusing alice's grid. A single
    fleet-wide worker slot serializes the workers, so cross-tenant cache
    coalescing is deterministic."""
    tmp = tmp_path_factory.mktemp("service_e2e")
    root = tmp / "svc"
    out = {}
    with service_daemon(root, "--max-workers", "1") as url:
        out["health_boot"] = _get(url, "/healthz")
        _submit(url, "alice", TENANT_GRIDS["alice"])
        _submit(url, "bob", TENANT_GRIDS["bob"])
        _submit(url, "pat", TENANT_GRIDS["alice"], objective="pareto")
        out["index"] = _wait_drained(url, ["alice", "bob", "pat"])
        out["health_drained"] = _get(url, "/healthz")
        for t in ("alice", "bob", "pat"):
            out[f"status_{t}"] = _get(url, f"/tenants/{t}")
            out[f"lb_{t}"] = _get_bytes(url, f"/tenants/{t}/leaderboard")
    out["root"] = root
    out["ref_dir"] = tmp
    return out


@pytest.mark.slow
def test_service_lifecycle_daemon_never_imports_jax(service_run):
    for key in ("health_boot", "health_drained"):
        h = service_run[key]
        assert h["ok"] and h["jax_loaded"] is False
    for t in ("alice", "bob", "pat"):
        s = service_run[f"status_{t}"]
        assert s["drained"] and s["queue"]["done"] == 2
        assert all(w["state"] == "done" and w["restarts"] == 0
                   for w in s["workers"])
        assert s["submissions"][0]["seeded"] == 2


@pytest.mark.slow
def test_cross_tenant_dedupe_compiles_each_design_once(service_run):
    cache = service_run["root"] / "dryrun_cache"
    per_cell = {}
    for f in cache.glob("*.json"):
        rec = json.loads(f.read_text())
        key = (rec["arch"], rec["shape"])
        per_cell[key] = per_cell.get(key, 0) + 1
    # union of the two grids = 3 unique cells; every design appears once
    assert set(per_cell) == {("qwen3-0.6b", "train_4k"),
                             ("qwen3-0.6b", "decode_32k"),
                             ("stablelm-3b", "train_4k")}
    # the shared cell holds exactly one compile set, not one per tenant
    designs_per_cell = PROFILE["budget"] + 1  # proposals + baseline
    assert all(n == designs_per_cell for n in per_cell.values()), per_cell
    # fleet-wide compile count == unique designs: nothing compiled twice
    compiles = sum(w["compiles_total"]
                   for t in ("alice", "bob", "pat")
                   for w in service_run[f"status_{t}"]["workers"])
    assert compiles == sum(per_cell.values())
    # pat (same grid as alice, later in the serialized fleet) replayed
    # everything from the shared cache: zero compiles of its own
    assert sum(w["compiles_total"]
               for w in service_run["status_pat"]["workers"]) == 0


@pytest.mark.slow
def test_tenant_leaderboards_byte_identical_to_standalone(service_run):
    ref = service_run["ref_dir"]
    for tenant, objective in (("alice", "bound_s"), ("bob", "bound_s"),
                              ("pat", "pareto")):
        grid = TENANT_GRIDS["alice" if tenant == "pat" else tenant]
        want = _standalone_leaderboard(ref / f"ref_{tenant}", grid,
                                       objective=objective)
        assert service_run[f"lb_{tenant}"] == want, (
            f"tenant {tenant} leaderboard drifted from the standalone "
            f"campaign run")


@pytest.mark.slow
def test_stalled_tenant_cannot_starve_another(tmp_path):
    """Tenant isolation: park a foreign never-expiring lease on one
    tenant's only cell (a stalled queue: backlog that no worker can
    take), and the other tenant must still be scheduled and drain."""
    root = tmp_path / "svc"
    with service_daemon(root, "--max-workers", "2") as url:
        _submit(url, "stuck", {"archs": "stablelm-3b",
                               "shapes": "decode_32k"})
        # steal the cell out from under the tenant's workers with a
        # foreign 1-hour lease before any worker can claim it
        q = CellQueue(root / "tenants" / "stuck" / "queue", lease_s=3600)
        deadline = time.time() + 30
        ticket = None
        while ticket is None and time.time() < deadline:
            ticket = q.acquire("outsider")
            if ticket is None:
                time.sleep(0.1)
        assert ticket is not None, "could not park the blocking lease"
        _submit(url, "fast", TENANT_GRIDS["alice"])
        deadline = time.time() + 420
        while time.time() < deadline:
            idx = _get(url, "/tenants")["tenants"]
            fast_done = (idx["fast"]["queue"]["pending"] == 0
                         and idx["fast"]["queue"]["leased"] == 0
                         and idx["fast"]["queue"]["done"] == 2)
            if fast_done:
                break
            time.sleep(1.0)
        assert fast_done, f"fast tenant starved: {idx}"
        # the stalled tenant is still stalled — fast didn't wait for it
        stuck = _get(url, "/tenants/stuck")
        assert stuck["queue"]["leased"] == 1 and stuck["queue"]["done"] == 0
