"""Fair-share policy: pure worker-grant decisions for the DSE service."""
from repro.core.fairshare import (GrantPlan, TenantSnapshot, budget_left,
                                  over_budget, plan_worker_grants)


def _t(name, **kw):
    kw.setdefault("backlog", 4)
    return TenantSnapshot(name=name, **kw)


def test_budget_accounting():
    assert budget_left(None, 100) is None
    assert budget_left(5, 2) == 3
    assert budget_left(5, 9) == 0
    assert not over_budget(None, 10 ** 6)
    assert not over_budget(5, 4)
    assert over_budget(5, 5)


def test_equal_priority_splits_slots_evenly():
    plan = plan_worker_grants([_t("a"), _t("b")], free_slots=4,
                              max_workers_per_tenant=4)
    assert sorted(plan.grants) == ["a", "a", "b", "b"]


def test_priority_weights_grant_share():
    tenants = [_t("hi", priority=2, backlog=8), _t("lo", priority=1, backlog=8)]
    plan = plan_worker_grants(tenants, free_slots=3,
                              max_workers_per_tenant=8)
    assert plan.grants.count("hi") == 2 and plan.grants.count("lo") == 1


def test_backlog_caps_grants():
    # one pending cell never earns a second worker
    plan = plan_worker_grants([_t("a", backlog=1), _t("b", backlog=6)],
                              free_slots=4, max_workers_per_tenant=4)
    assert plan.grants.count("a") == 1
    assert plan.grants.count("b") == 3


def test_exhausted_budget_is_skipped():
    tenants = [_t("spent", budget_cells=3, cells_done=3), _t("fresh")]
    plan = plan_worker_grants(tenants, free_slots=2)
    assert plan.grants == ["fresh", "fresh"]


def test_stalled_tenant_cannot_absorb_slots():
    tenants = [_t("stuck", priority=9, stalled=True), _t("ok")]
    plan = plan_worker_grants(tenants, free_slots=2)
    assert all(g == "ok" for g in plan.grants)


def test_credits_carry_fairness_across_ticks():
    # pool of one slot: alternating ticks should alternate the winner
    winners = []
    credits = {"a": 0.0, "b": 0.0}
    for _ in range(4):
        snap = [TenantSnapshot("a", backlog=4, credit=credits["a"]),
                TenantSnapshot("b", backlog=4, credit=credits["b"])]
        plan = plan_worker_grants(snap, free_slots=1)
        winners.extend(plan.grants)
        credits = plan.credits
    assert winners.count("a") == 2 and winners.count("b") == 2


def test_grants_deterministic_under_permutation():
    tenants = [_t("c", priority=1), _t("a", priority=3), _t("b", priority=2)]
    plan_fwd = plan_worker_grants(tenants, free_slots=5,
                                  max_workers_per_tenant=5)
    plan_rev = plan_worker_grants(list(reversed(tenants)), free_slots=5,
                                  max_workers_per_tenant=5)
    assert plan_fwd == GrantPlan(plan_rev.grants, plan_rev.credits)


def test_no_eligible_tenants_returns_empty_plan():
    plan = plan_worker_grants([_t("idle", backlog=0)], free_slots=3)
    assert plan.grants == []
