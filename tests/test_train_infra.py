"""Training infrastructure: optimizer, compression, checkpoint, fault
tolerance, elastic restart, data pipeline."""
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from conftest import run_subprocess
from repro.configs import get_config, reduced
from repro.sharding.plan import ShardingPlan
from repro.train import checkpoint as ckpt
from repro.train import grad_compress as gc
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod
from repro.train.data import DataConfig, Prefetcher, SyntheticLM
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    c = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    st_ = opt_mod.init_opt_state(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, st_, _ = opt_mod.adamw_update(c, params, g, st_)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_lr_schedule_shape():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(opt_mod.lr_schedule(c, jnp.int32(0))) == 0.0
    assert float(opt_mod.lr_schedule(c, jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt_mod.lr_schedule(c, jnp.int32(100))) == pytest.approx(0.1, abs=0.01)


def test_grad_clipping_bounds_update():
    c = AdamWConfig(lr=0.1, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros((4,))}
    st_ = opt_mod.init_opt_state(params)
    _, _, m = opt_mod.adamw_update(c, params, {"w": 1e6 * jnp.ones((4,))}, st_)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), kind=st.sampled_from(["int8", "topk"]))
def test_compression_error_bounded_and_ef(seed, kind):
    g = {"w": jax.random.normal(jax.random.key(seed), (256,))}
    ef = gc.init_error_feedback(g)
    dec, ef2 = gc.compress_decompress(kind, g, ef)
    if kind == "int8":
        amax = float(jnp.abs(g["w"]).max())
        assert float(jnp.abs(dec["w"] - g["w"]).max()) <= amax / 127.0 + 1e-6
    # error feedback holds exactly the residual
    np.testing.assert_allclose(np.asarray(ef2["w"]),
                               np.asarray(g["w"] - dec["w"]), atol=1e-6)


def test_error_feedback_recovers_signal_over_steps():
    """A constant gradient below the top-k threshold must eventually pass."""
    g = {"w": jnp.concatenate([jnp.ones((2,)) * 10.0, jnp.ones((510,)) * 0.01])}
    ef = gc.init_error_feedback(g)
    total = jnp.zeros((512,))
    for _ in range(30):
        dec, ef = gc.compress_decompress("topk", g, ef)
        total = total + dec["w"]
    # small entries accumulate via EF and are transmitted eventually
    assert float(total[2:].sum()) > 0.25 * 30 * 0.01 * 510


def test_wire_bytes_factors():
    assert gc.wire_bytes_factor("int8") == 0.5
    assert gc.wire_bytes_factor("none") == 1.0
    assert gc.wire_bytes_factor("topk") < 0.1


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tiny_state():
    cfg = reduced(get_config("qwen3-0.6b"))
    plan = ShardingPlan(rules={}, remat="none", zero1=False)
    state, _ = step_mod.init_train_state(cfg, jax.random.key(0), plan)
    return cfg, plan, state


def test_checkpoint_roundtrip_bitexact(tmp_path):
    cfg, plan, state = _tiny_state()
    ckpt.save_checkpoint(tmp_path, 7, state, extra={"note": "x"})
    restored, step, extra = ckpt.restore_checkpoint(tmp_path, state)
    assert step == 7 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    cfg, plan, state = _tiny_state()
    ckpt.save_checkpoint(tmp_path, 5, state)
    ckpt.save_checkpoint(tmp_path, 9, state)
    os.remove(tmp_path / "step_00000009" / "COMMIT")  # simulate crash mid-write
    assert ckpt.latest_step(tmp_path) == 5


def test_resume_equals_uninterrupted(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    cfg, plan, state0 = _tiny_state()
    step = jax.jit(step_mod.make_train_step(cfg, plan, None,
                                            AdamWConfig(warmup_steps=1)))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))

    def run(state, a, b):
        for i in range(a, b):
            state, _ = step(state, {k: jnp.asarray(v)
                                    for k, v in data.batch(i).items()})
        return state

    straight = run(state0, 0, 6)
    half = run(state0, 0, 3)
    ckpt.save_checkpoint(tmp_path, 3, half)
    restored, s, _ = ckpt.restore_checkpoint(tmp_path, half)
    resumed = run(restored, 3, 6)
    for a, b in zip(jax.tree.leaves(straight["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault-tolerant trainer
# ---------------------------------------------------------------------------
def test_trainer_survives_injected_faults(tmp_path):
    cfg, plan, state = _tiny_state()
    step = jax.jit(step_mod.make_train_step(cfg, plan, None,
                                            AdamWConfig(warmup_steps=1)))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    boom = {11: True, 17: True}

    def fault(s):
        if boom.pop(s, None):
            raise RuntimeError(f"injected node failure at {s}")

    tr = Trainer(cfg, plan, step, state, data,
                 TrainerConfig(total_steps=24, ckpt_every=5, log_every=100,
                               ckpt_dir=str(tmp_path)),
                 fault_hook=fault)
    out = tr.run()
    assert out["final_step"] == 24
    assert not boom  # both faults fired
    losses = [h["loss"] for h in out["history"]]
    assert all(np.isfinite(losses))
    assert ckpt.latest_step(tmp_path) == 24


def test_trainer_gives_up_after_max_retries(tmp_path):
    cfg, plan, state = _tiny_state()
    step = jax.jit(step_mod.make_train_step(cfg, plan, None, AdamWConfig()))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))

    def always_fail(s):
        if s >= 2:
            raise RuntimeError("persistent failure")

    tr = Trainer(cfg, plan, step, state, data,
                 TrainerConfig(total_steps=10, ckpt_every=2, max_retries=2,
                               log_every=100, ckpt_dir=str(tmp_path)),
                 fault_hook=always_fail)
    with pytest.raises(RuntimeError, match="giving up"):
        tr.run()


def test_straggler_watchdog(tmp_path):
    cfg, plan, state = _tiny_state()
    inner = jax.jit(step_mod.make_train_step(cfg, plan, None, AdamWConfig()))
    import time

    calls = []

    def slow_step(state, batch):
        out = inner(state, batch)
        if len(calls) == 8:
            time.sleep(1.0)  # one straggling step
        calls.append(1)
        return out

    rebalanced = []
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    tr = Trainer(cfg, plan, slow_step, state, data,
                 TrainerConfig(total_steps=12, ckpt_every=50, log_every=100,
                               ckpt_dir=str(tmp_path), straggler_factor=3.0),
                 rebalance_hook=rebalanced.append)
    tr.run()
    assert tr.stragglers and rebalanced


# ---------------------------------------------------------------------------
# elastic restart (different device count) — subprocess with 8 fake devices
# ---------------------------------------------------------------------------
def test_elastic_reshard_across_device_counts(tmp_path):
    out = run_subprocess(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.sharding.plan import ShardingPlan, baseline_rules
        from repro.train import step as step_mod, checkpoint as ckpt
        from repro.train.elastic import rebuild, choose_mesh_shape
        from repro.train.data import DataConfig, SyntheticLM
        from repro.train.optimizer import AdamWConfig
        from repro.launch.mesh import make_mesh

        cfg = reduced(get_config("qwen3-0.6b"))
        plan = ShardingPlan(rules=baseline_rules(), remat="none")
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8))

        # train 2 steps on an 8-device (4,2) mesh
        mesh8 = make_mesh((4, 2), ("data", "model"))
        jstep, abstract, (s_shard, _) = step_mod.jit_train_step(
            cfg, plan, mesh8, AdamWConfig(warmup_steps=1), donate=False)
        state, _ = step_mod.init_train_state(cfg, jax.random.key(0), plan)
        state = jax.device_put(state, s_shard)
        for i in range(2):
            state, _ = jstep(state, {{k: jnp.asarray(v) for k, v in data.batch(i).items()}})
        ckpt.save_checkpoint(r"{tmp_path}", 2, state)

        # 'lose' half the pod: restore onto 4 devices and keep training
        state4, mesh4, jstep4, step = rebuild(cfg, plan, r"{tmp_path}", devices=4)
        assert step == 2 and mesh4.size == 4, (step, mesh4)
        loss = None
        for i in range(2, 4):
            state4, m = jstep4(state4, {{k: jnp.asarray(v) for k, v in data.batch(i).items()}})
            loss = float(m["loss"])
        assert np.isfinite(loss)

        # and scale back up to 8
        ckpt.save_checkpoint(r"{tmp_path}", 4, state4)
        state8, mesh8b, jstep8, step = rebuild(cfg, plan, r"{tmp_path}", devices=8)
        assert step == 4 and mesh8b.size == 8
        state8, m = jstep8(state8, {{k: jnp.asarray(v) for k, v in data.batch(4).items()}})
        print("ELASTIC_OK", float(m["loss"]))
    """, n_devices=8)
    assert "ELASTIC_OK" in out


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_host_sharded():
    c = DataConfig(vocab=100, seq_len=8, global_batch=8, seed=1, n_hosts=2, host_id=0)
    a = SyntheticLM(c).batch(3)
    b = SyntheticLM(c).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    other = SyntheticLM(DataConfig(vocab=100, seq_len=8, global_batch=8, seed=1,
                                   n_hosts=2, host_id=1)).batch(3)
    assert not np.array_equal(a["tokens"], other["tokens"])
    assert a["tokens"].shape == (4, 8)  # global 8 over 2 hosts
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["targets"][:, :-1])


def test_prefetcher_delivers_in_order():
    src = SyntheticLM(DataConfig(vocab=50, seq_len=4, global_batch=2))
    pf = Prefetcher(src, depth=2)
    try:
        b0 = pf.next()
        np.testing.assert_array_equal(b0["tokens"], src.batch(0)["tokens"])
        b1 = pf.next()
        np.testing.assert_array_equal(b1["tokens"], src.batch(1)["tokens"])
    finally:
        pf.close()
