"""Prefill+decode must reproduce full-forward logits (per family)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M

FAMS = ["llama3-8b", "mamba2-780m", "zamba2-2.7b", "seamless-m4t-medium",
        "llava-next-34b", "mixtral-8x7b"]


def _nodrop(cfg):
    if cfg.moe is None:
        return cfg
    cf = float(cfg.moe.n_experts) / cfg.moe.top_k  # capacity >= group: no drops
    return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_full_forward(name):
    cfg = _nodrop(reduced(get_config(name)))
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    b, s = 2, 24
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = 0.1 * jnp.ones((b, cfg.frontend_len, 1024), jnp.float32)

    cache = M.init_cache(cfg, b, 64)
    lp, cache = M.prefill_fn(cfg, params, batch, cache)
    nxt = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
    ld, _ = M.decode_fn(cfg, params, {"tokens": nxt}, cache)

    ref_cache = M.init_cache(cfg, b, 64)
    batch2 = dict(batch, tokens=jnp.concatenate([toks, nxt], 1))
    lr, _ = M.prefill_fn(cfg, params, batch2, ref_cache)

    err = float(jnp.max(jnp.abs(ld[:, -1] - lr[:, -1])))
    scale = float(jnp.max(jnp.abs(lr))) + 1e-9
    assert err / scale < 2e-2, f"{name}: rel err {err/scale:.3e}"


def test_swa_ring_buffer_eviction():
    """Tokens beyond the SWA window must be evicted from the rolling cache."""
    cfg = reduced(get_config("mixtral-8x7b"))  # window=16
    cfg = _nodrop(cfg)
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    b, s = 1, 24  # prompt longer than the window
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    cache = M.init_cache(cfg, b, 64)
    assert cache["k"].shape[2] == cfg.swa_window  # ring buffer is window-sized
    lp, cache = M.prefill_fn(cfg, params, {"tokens": toks}, cache)
    nxt = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
    ld, cache = M.decode_fn(cfg, params, {"tokens": nxt}, cache)
    assert np.isfinite(np.asarray(ld, np.float32)).all()
    assert int(cache["len"][0]) == s + 1
