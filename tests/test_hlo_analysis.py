"""HLO analyzer exactness: trip-count-multiplied flops/collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.hlo_analysis import analyze_hlo
from conftest import run_subprocess


@settings(max_examples=8, deadline=None)
@given(L=st.integers(2, 9), M=st.sampled_from([32, 64]),
       K=st.sampled_from([64, 128]), N=st.sampled_from([64, 128]))
def test_scan_matmul_flops_exact(L, M, K, N):
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, K, K), jnp.float32),
        jax.ShapeDtypeStruct((M, K), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text(), 1)
    assert res["flops"] == pytest.approx(2 * M * K * K * L, rel=1e-6)


def test_xla_cost_analysis_undercounts_while():
    """Motivation: XLA counts while bodies once; our analyzer multiplies."""
    def f(w, x):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    from repro.launch.dryrun import xla_cost_dict

    M = K = 64
    flops = {}
    for L in (2, 8):
        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((L, K, K), jnp.float32),
            jax.ShapeDtypeStruct((M, K), jnp.float32)).compile()
        flops[L] = (xla_cost_dict(comp).get("flops", 0.0),
                    analyze_hlo(comp.as_text(), 1)["flops"])
    assert flops[2][0] == flops[8][0]  # XLA: body counted once
    assert flops[8][1] == pytest.approx(4 * flops[2][1], rel=1e-6)  # ours: x L


def test_collective_bytes_sharded():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        L, M, K = 5, 64, 128
        def f(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return y.sum()
        with mesh:
            comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")), None)) \\
                .lower(jax.ShapeDtypeStruct((L, K, K), jnp.float32),
                       jax.ShapeDtypeStruct((M, K), jnp.float32)).compile()
        res = analyze_hlo(comp.as_text(), 8)
        print(json.dumps({"flops": res["flops"],
                          "ar": res["collect_bytes"].get("all-reduce", 0)}))
    """, n_devices=8)
    import json

    r = json.loads(out.strip().splitlines()[-1])
    # per-device: L x (M x K/4 x K) matmul
    assert r["flops"] == pytest.approx(2 * 64 * 32 * 128 * 5, rel=1e-6)
    # all-reduce payload: L x result (64x128 f32) + the scalar loss reduce
    assert r["ar"] == pytest.approx(5 * 64 * 128 * 4, rel=0.01)


def test_fusion_dynamic_slice_charging():
    """Scan-over-layers param reads must charge one layer per iteration."""
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    L, K = 16, 128
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, K, K), jnp.float32),
        jax.ShapeDtypeStruct((8, K), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text(), 1)
    # Convention: operand+result bytes per op (like HloCostAnalysis), so one
    # layer read ~ 2-4x its size; the property under test is that the stacked
    # params are charged as ONE layer per iteration (L x), not the whole
    # stack each iteration (L^2 x).
    assert res["hbm_bytes"] < 6 * L * K * K * 4  # linear in L
    assert res["hbm_bytes"] > 0.5 * L * K * K * 4
    assert res["hbm_bytes"] < 0.5 * L * L * K * K * 4  # NOT quadratic
