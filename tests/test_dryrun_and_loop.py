"""Dry-run driver + full SECDA-DSE loop (subprocess, reduced device counts)."""
import json

import pytest

from conftest import run_subprocess
from repro.configs import ARCH_NAMES, SHAPES, SHAPE_BY_NAME, get_config
from repro.models import model as M


# ---------------------------------------------------------------------------
# input_specs: every (arch x shape) cell is well-defined without allocation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", [s.name for s in SHAPES])
def test_input_specs_all_cells(arch, shape):
    cfg = get_config(arch)
    cell = SHAPE_BY_NAME[shape]
    ok, why = M.cell_supported(cfg, cell)
    if not ok:
        assert shape == "long_500k" and not cfg.sub_quadratic()
        return
    specs = M.input_specs(cfg, cell)
    assert "batch" in specs
    toks = specs["batch"]["tokens"]
    if cell.kind == "decode":
        assert toks.shape == (cell.global_batch, 1)
        assert "cache" in specs
    elif cfg.family == "vlm":
        F = cfg.frontend_len
        assert toks.shape[1] == cell.seq_len - F
        assert specs["batch"]["frontend"].shape == (cell.global_batch, F, 1024)
    else:
        assert toks.shape == (cell.global_batch, cell.seq_len)
    # nothing in the tree is a concrete array
    import jax

    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long500k_runs_only_for_subquadratic():
    runs = [a for a in ARCH_NAMES
            if M.cell_supported(get_config(a), SHAPE_BY_NAME["long_500k"])[0]]
    assert sorted(runs) == ["mamba2-780m", "mixtral-8x7b", "zamba2-2.7b"]


# ---------------------------------------------------------------------------
# dry-run driver on a reduced mesh (subprocess: forces 8 host devices)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_dryrun_cell_small_mesh(tmp_path):
    out = run_subprocess(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        from repro.launch.dryrun import run_cell
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rec = run_cell("qwen3-0.6b", "decode_32k", mesh, "small2x4",
                       artifact_dir=__import__("pathlib").Path(r"{tmp_path}"))
        assert rec["status"] == "ok", rec
        r = rec["roofline"]
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert rec["hlo"]["flops"] > 0
        assert rec["model_flops"] == 2.0 * rec["model_flops_per_dev"] * 8 / 2
        print("DRYRUN_OK", r["dominant"])
    """, n_devices=8, timeout=900)
    assert "DRYRUN_OK" in out
    rec = json.loads((tmp_path / "qwen3-0.6b__decode_32k__small2x4.json").read_text())
    assert rec["status"] == "ok"
    assert rec["memory"]["per_device_bytes"] > 0


def test_production_mesh_artifacts_complete():
    """The committed artifact set must cover all 40 cells x both meshes."""
    from pathlib import Path

    adir = Path("artifacts/dryrun")
    if not adir.exists():
        pytest.skip("dry-run artifacts not generated yet")
    for mesh in ("pod16x16", "multipod2x16x16"):
        for arch in ARCH_NAMES:
            for cell in SHAPES:
                f = adir / f"{arch}__{cell.name}__{mesh}.json"
                assert f.exists(), f"missing dry-run cell {f.name}"
                rec = json.loads(f.read_text())
                assert rec["status"] in ("ok", "skipped"), \
                    f"{f.name}: {rec.get('error', rec['status'])}"
                supported, _ = M.cell_supported(get_config(arch), cell)
                assert (rec["status"] == "ok") == supported


# ---------------------------------------------------------------------------
# the full SECDA-DSE loop on a 1x1 mesh with a monkeypatched tiny config
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_dse_loop_end_to_end(tmp_path):
    out = run_subprocess(f"""
        import dataclasses, json
        import repro.configs as C
        from repro.configs import get_config as real_get, reduced
        from repro.configs.base import ShapeCell

        tiny_cell = ShapeCell("train_4k", "train", 64, 8)  # reuse the cell name
        C.SHAPE_BY_NAME["train_4k"] = tiny_cell
        tiny = reduced(real_get("qwen3-0.6b"))
        import repro.launch.dryrun as D
        import repro.core.evaluator as E
        for mod in (D, E):
            mod.get_config = lambda name: tiny
            mod.SHAPE_BY_NAME = C.SHAPE_BY_NAME

        from repro.core.cost_db import CostDB, featurize
        from repro.core.cost_model import CostModel
        from repro.core.evaluator import Evaluator
        from repro.core.llm_client import MockLLM
        from repro.core.llm_stack import LLMStack
        from repro.core.loop import DSELoop
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        db = CostDB(r"{tmp_path}/db.jsonl")
        loop = DSELoop(
            evaluator=Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}"),
            db=db, llm_stack=LLMStack(client=MockLLM(), db=db),
            cost_model=CostModel.create(in_dim=featurize({{}}, {{}}).shape[0]))
        report = loop.run("qwen3-0.6b", "train_4k", iterations=2,
                          eval_budget=2, verbose=False)
        assert report.baseline is not None and report.baseline.status == "ok"
        assert report.best is not None
        assert len(db.all()) >= 5  # baseline + 2 iters x 2 evals
        assert report.improvement() <= 1.001
        print("LOOP_OK", report.improvement())
    """, n_devices=1, timeout=900)
    assert "LOOP_OK" in out
