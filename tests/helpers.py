from conftest import run_subprocess, REPO, SRC  # re-export
