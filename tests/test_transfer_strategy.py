"""TransferSeeded cross-workload strategy + the CostDB donor queries it
leans on (winners, iteration_batches) + Ensemble credit rebuild from the
DB source field (the resume-keeps-its-learned-allocation contract)."""
import pytest

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint, workload_features
from repro.core.design_space import PlanPoint, PlanTemplate, baseline_point
from repro.search import (Ensemble, SearchState, TransferSeeded,
                          make_strategy)
from repro.search.transfer import adapt_point

MESH = {"data": 16, "model": 16}
ARCH, SHAPE = "llama3-8b", "train_4k"


def _template(arch=ARCH, shape=SHAPE):
    return PlanTemplate(get_config(arch), SHAPE_BY_NAME[shape], MESH)


def _dp(arch=ARCH, shape=SHAPE, bound=1.0, status="ok", source="expert",
        iteration=1, ts=None, **dims) -> DataPoint:
    cfg, cell = get_config(arch), SHAPE_BY_NAME[shape]
    t = _template(arch, shape)
    p = PlanPoint(dims={**baseline_point(cell, t).dims, **dims})
    kw = {} if ts is None else {"ts": ts}
    return DataPoint(arch=arch, shape=shape, mesh="m",
                     point={**p.dims, "__key__": p.key()}, status=status,
                     source=source, iteration=iteration,
                     metrics={"workload": workload_features(cfg, cell),
                              "bound_s": bound, "fits_hbm": status == "ok"},
                     **kw)


def _state(db, arch=ARCH, shape=SHAPE, incumbent=None, budget=3,
           iteration=1) -> SearchState:
    cfg, cell = get_config(arch), SHAPE_BY_NAME[shape]
    return SearchState(arch=arch, shape=shape, cfg=cfg, cell=cell,
                       template=_template(arch, shape), db=db,
                       iteration=iteration, budget=budget,
                       incumbent=incumbent,
                       pool=[incumbent] if incumbent else [],
                       workload=workload_features(cfg, cell))


# ---------------------------------------------------------------------------
# CostDB donor queries
# ---------------------------------------------------------------------------
def test_winners_ranks_feasible_designs_dedup_by_key(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp(bound=3.0, remat="dots", ts=1.0))
    db.append(_dp(bound=1.0, remat="none", ts=2.0))
    db.append(_dp(bound=9.0, status="infeasible", microbatches=2, ts=3.0))
    db.append(_dp(bound=2.0, remat="none", ts=4.0))  # same design, later+slower
    w = db.winners(ARCH, SHAPE, k=5)
    assert [d.metrics["bound_s"] for d in w] == [1.0, 3.0]  # infeasible out, deduped
    assert db.winners(ARCH, SHAPE, k=1)[0].metrics["bound_s"] == 1.0
    assert db.winners("other", SHAPE) == []


def test_costdb_tolerates_torn_tail_line(tmp_path):
    """A SIGKILL mid-append leaves a truncated last JSONL line; the DB must
    skip it (resume over crash debris), not raise."""
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp(bound=1.0, remat="none"))
    db.append(_dp(bound=2.0, remat="dots"))
    text = (tmp_path / "db.jsonl").read_text()
    (tmp_path / "db.jsonl").write_text(text + text.splitlines()[0][:40])
    fresh = CostDB(tmp_path / "db.jsonl")
    assert len(fresh.all()) == 2
    assert fresh.best(ARCH, SHAPE).metrics["bound_s"] == 1.0


def test_iteration_batches_groups_in_order(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp(bound=4.0, iteration=2, remat="dots"))
    db.append(_dp(bound=5.0, iteration=0, source="expert"))
    db.append(_dp(bound=3.0, iteration=2, remat="none"))
    db.append(_dp(bound=2.0, iteration=5, microbatches=2))
    batches = db.iteration_batches(ARCH, SHAPE)
    assert [it for it, _ in batches] == [0, 2, 5]
    assert [d.metrics["bound_s"] for d in dict(batches)[2]] == [4.0, 3.0]


# ---------------------------------------------------------------------------
# donor ranking + template adaptation
# ---------------------------------------------------------------------------
def test_donor_cells_prefer_similar_workloads(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    # target: llama3-8b decode; donors: a decode cell and a train cell
    db.append(_dp(arch="qwen3-0.6b", shape="decode_32k", bound=1.0))
    db.append(_dp(arch="qwen3-0.6b", shape="train_4k", bound=1.0))
    db.append(_dp(arch="mamba2-780m", shape="train_4k", bound=9.0,
                  status="infeasible"))  # no feasible row -> not a donor
    ts = TransferSeeded()
    ranked = ts.donor_cells(_state(db, arch=ARCH, shape="decode_32k"))
    assert [c[1:] for c in ranked] == [("qwen3-0.6b", "decode_32k"),
                                       ("qwen3-0.6b", "train_4k")]
    assert ranked[0][0] > ranked[1][0]  # strictly more similar


def test_donor_and_credit_queries_are_mesh_scoped(tmp_path):
    """A DB re-run under another --mesh holds both meshes' rows; scoped
    lookups must never mix them (a cross-mesh bound is not comparable)."""
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp(arch="qwen3-0.6b", shape=SHAPE, bound=1.0))  # mesh "m"
    other = _dp(arch="mamba2-780m", shape=SHAPE, bound=0.1)
    other.mesh = "other-mesh"
    db.append(other)
    ts = TransferSeeded()
    state = _state(db, arch=ARCH, shape=SHAPE)
    state.mesh = "m"
    assert [c[1] for c in ts.donor_cells(state)] == ["qwen3-0.6b"]
    state.mesh = None  # unscoped keeps the legacy behavior
    assert len(TransferSeeded().donor_cells(state)) == 2

    db.append(_dp(bound=0.5, iteration=1, source="search:b", remat="dots"))
    fast_elsewhere = _dp(bound=0.01, iteration=1, source="search:a",
                         remat="none")
    fast_elsewhere.mesh = "other-mesh"
    db.append(fast_elsewhere)
    scoped = Ensemble([_Stub("a"), _Stub("b")], warm_start=False)
    scoped.rebuild_credit(db, ARCH, SHAPE, mesh="m")
    assert scoped._best_seen == 0.5  # the other mesh's 0.01 never leaked
    assert scoped.credit["a"] == 0.0


def test_adapt_point_snaps_illegal_dims_to_target_template(tmp_path):
    # a train winner (remat=full, microbatches=2) transplanted into a decode
    # cell, where both values are illegal
    train_t = _template(ARCH, "train_4k")
    decode_t = _template(ARCH, "decode_32k")
    donor = PlanPoint(dims={**baseline_point(SHAPE_BY_NAME["train_4k"],
                                             train_t).dims,
                            "remat": "full", "microbatches": 2})
    fb = baseline_point(SHAPE_BY_NAME["decode_32k"], decode_t)
    adapted = adapt_point(decode_t, donor, fb)
    assert adapted is not None
    ok, why = decode_t.validate(adapted)
    assert ok, why
    assert adapted.dims["remat"] == "none" and adapted.dims["microbatches"] == 1


def test_transfer_proposes_transplants_then_polish(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    donor_best = _dp(arch="qwen3-0.6b", shape=SHAPE, bound=0.5, remat="dots")
    db.append(donor_best)
    db.append(_dp(arch="qwen3-0.6b", shape=SHAPE, bound=1.5, zero1=False))
    ts = TransferSeeded(seed=0, per_donor=2)
    inc = _dp(bound=4.0)
    cands = ts.propose(_state(db, incumbent=inc, budget=4))
    assert len(cands) == 4
    assert all(c.source == "search:transfer" for c in cands)
    t = _template()
    for c in cands:
        ok, why = t.validate(c.point)
        assert ok, why
    # the donor's winning dims lead the proposal list
    assert cands[0].point.dims["remat"] == "dots"
    # observing an own win re-bases later polish on it; proposals stay
    # deterministic for a fixed seed
    won = cands[0].point
    ts.observe([DataPoint(arch=ARCH, shape=SHAPE, mesh="m",
                          point={**won.dims, "__key__": won.key()},
                          status="ok", metrics={"bound_s": 0.7})])
    assert ts._best_own[1] == 0.7
    nxt = ts.propose(_state(db, incumbent=inc, budget=3, iteration=2))
    assert len(nxt) == 3
    ts2 = TransferSeeded(seed=0, per_donor=2)
    again = ts2.propose(_state(db, incumbent=inc, budget=4))
    assert [c.point.key() for c in again] == [c.point.key() for c in cands]


def test_transfer_empty_db_falls_back_to_random_exploration(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    cands = TransferSeeded(seed=1).propose(_state(db, budget=3))
    assert len(cands) == 3
    t = _template()
    for c in cands:
        ok, why = t.validate(c.point)
        assert ok, why


def test_registry_builds_transfer_variants():
    assert type(make_strategy("transfer")).__name__ == "TransferSeeded"
    ens = make_strategy("ensemble+transfer")
    assert isinstance(ens, Ensemble)
    assert "transfer" in {m.name for m in ens.members}
    plain = make_strategy("ensemble")
    assert "transfer" not in {m.name for m in plain.members}


# ---------------------------------------------------------------------------
# Ensemble credit rebuild from the DB source field (resume contract)
# ---------------------------------------------------------------------------
class _Stub:
    """Named no-op member: the ledger only needs names."""

    def __init__(self, name):
        self.name = name

    def propose(self, state):
        return []

    def observe(self, dps):
        pass


def _improvement_stream():
    """(iteration, rows) script: b keeps improving, a improves once late."""
    return [
        (0, [_dp(bound=4.0, iteration=0, source="expert")]),
        (1, [_dp(bound=3.0, iteration=1, source="search:b", remat="dots")]),
        (2, [_dp(bound=5.0, iteration=2, source="search:a", zero1=False),
             _dp(bound=2.0, iteration=2, source="search:b", remat="none")]),
        (3, [_dp(bound=6.0, iteration=3, source="search:a", microbatches=2,
                 status="infeasible")]),
        (4, [_dp(bound=1.0, iteration=4, source="search:a", microbatches=4)]),
    ]


def test_rebuilt_credit_matches_in_memory_allocator(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    live = Ensemble([_Stub("a"), _Stub("b")], warm_start=False)
    for it, rows in _improvement_stream():
        db.append_many(rows)
        if it >= 1:  # the loop calls observe once per iteration >= 1
            live.observe(rows)
        else:  # iteration 0 = the expert seed the loop evaluates directly
            live._best_seen = rows[0].metrics["bound_s"]

    rebuilt = Ensemble([_Stub("a"), _Stub("b")], warm_start=False)
    rebuilt.rebuild_credit(db, ARCH, SHAPE)
    assert rebuilt.credit == pytest.approx(live.credit)
    assert rebuilt._best_seen == live._best_seen == 1.0
    # the learned allocation survives the rebuild
    assert rebuilt.allocation(10) == live.allocation(10)


def test_rebuilt_credit_decays_across_iteration_gaps(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    live = Ensemble([_Stub("a"), _Stub("b")], warm_start=False)
    script = {0: [_dp(bound=4.0, iteration=0, source="expert")],
              1: [_dp(bound=3.0, iteration=1, source="search:b", remat="dots")],
              4: [_dp(bound=2.0, iteration=4, source="search:a",
                      remat="none")]}
    live._best_seen = 4.0
    for it in (1, 2, 3, 4):  # iterations 2 and 3 evaluated nothing recordable
        live.observe(script.get(it, []))
    for rows in script.values():
        db.append_many(rows)
    rebuilt = Ensemble([_Stub("a"), _Stub("b")], warm_start=False)
    rebuilt.rebuild_credit(db, ARCH, SHAPE)
    assert rebuilt.credit == pytest.approx(live.credit)


def test_warm_start_rebuilds_on_first_propose(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    for _, rows in _improvement_stream():
        db.append_many(rows)
    ens = Ensemble([_Stub("a"), _Stub("b")])  # warm_start defaults on
    assert ens.credit == {"a": 0.0, "b": 0.0}
    ens.propose(_state(db, budget=2))
    assert ens.credit["a"] > 0 and ens.credit["b"] > 0
    assert ens._best_seen == 1.0
    # cold start on a cell with no history stays all-zero
    cold = Ensemble([_Stub("a"), _Stub("b")])
    cold.propose(_state(db, shape="decode_32k", budget=2))
    assert cold.credit == {"a": 0.0, "b": 0.0}
