"""Campaign engine: batch==serial equivalence, dry-run cache, resume."""
import json

import pytest

from conftest import run_subprocess
from repro.core.eval_cache import DryRunCache


# the monkeypatch prologue shared by the subprocess tests: a tiny config +
# 64-token cells so dry-run compiles take seconds, mirroring
# test_dryrun_and_loop.test_dse_loop_end_to_end
TINY_PRELUDE = """
        import repro.configs as C
        from repro.configs import get_config as real_get, reduced
        from repro.configs.base import ShapeCell

        C.SHAPE_BY_NAME["train_4k"] = ShapeCell("train_4k", "train", 64, 8)
        C.SHAPE_BY_NAME["decode_32k"] = ShapeCell("decode_32k", "decode", 64, 4)
        tiny = reduced(real_get("qwen3-0.6b"))
        import repro.launch.dryrun as D
        import repro.core.evaluator as E
        for mod in (D, E):
            mod.get_config = lambda name: tiny
            mod.SHAPE_BY_NAME = C.SHAPE_BY_NAME

        from repro.core.design_space import PlanTemplate, baseline_point
        from repro.core.eval_cache import DryRunCache
        from repro.core.evaluator import Evaluator
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("data", "model"))
        cell = C.SHAPE_BY_NAME["train_4k"]
        template = PlanTemplate(tiny, cell, dict(mesh.shape))
        base = baseline_point(cell, template)"""


# ---------------------------------------------------------------------------
# cache: pure-python behavior, no jax required
# ---------------------------------------------------------------------------
def test_dryrun_cache_roundtrip(tmp_path):
    c = DryRunCache(tmp_path / "cache")
    assert c.get("a1", "s1", "m1", "k1") is None
    c.put("a1", "s1", "m1", "k1", {"status": "ok", "compile_s": 1.5})
    assert c.get("a1", "s1", "m1", "k1")["compile_s"] == 1.5
    # a different identity tuple is a different entry
    assert c.get("a1", "s1", "m2", "k1") is None
    # persistence: a fresh instance over the same directory serves the entry
    c2 = DryRunCache(tmp_path / "cache")
    assert c2.get("a1", "s1", "m1", "k1")["status"] == "ok"
    assert c2.stats() == {"hits": 1, "misses": 0, "entries": 1}
    assert c.stats()["misses"] == 2


def test_dryrun_cache_beside_db(tmp_path):
    c = DryRunCache.beside(tmp_path / "dse" / "cost_db.jsonl")
    assert c.root == tmp_path / "dse" / "dryrun_cache"
    assert c.root.is_dir()


def test_dryrun_cache_corruption_is_a_miss(tmp_path):
    """A truncated/invalid cache entry must read as a miss (recompile), never
    crash the batch or poison the campaign resume path."""
    rec = {"status": "ok", "compile_s": 1.5, "roofline": {"bound_s": 2.0}}
    c = DryRunCache(tmp_path / "cache")
    c.put("a1", "s1", "m1", "k1", rec)
    entry = c.root / f"{DryRunCache.key_for('a1', 's1', 'm1', 'k1')}.json"

    for corruption in (json.dumps(rec)[: len(json.dumps(rec)) // 2],  # truncated
                       "", "not json at all {{{"):
        entry.write_text(corruption)
        fresh = DryRunCache(tmp_path / "cache")  # no warm in-memory copy
        assert fresh.get("a1", "s1", "m1", "k1") is None
        assert fresh.stats()["misses"] == 1
        # the recompile's put() repairs the entry for the next reader
        fresh.put("a1", "s1", "m1", "k1", rec)
        assert DryRunCache(tmp_path / "cache").get("a1", "s1", "m1", "k1") == rec

    # the corrupted file never poisons an already-warm instance either
    entry.write_text("garbage")
    assert c.get("a1", "s1", "m1", "k1") == rec  # served from memory


def test_leaderboard_ranks_and_keeps_failures(tmp_path):
    from repro.core.cost_db import CostDB, DataPoint
    from repro.launch.campaign import build_leaderboard

    db = CostDB(tmp_path / "db.jsonl")
    db.append(DataPoint(arch="a1", shape="s", mesh="m", point={"__key__": "k1"},
                        status="ok", metrics={"bound_s": 2.0, "fits_hbm": True}))
    db.append(DataPoint(arch="a2", shape="s", mesh="m", point={"__key__": "k2"},
                        status="ok", metrics={"bound_s": 1.0, "fits_hbm": True}))
    rows = build_leaderboard(db, [
        {"arch": "a1", "shape": "s", "mesh": "m", "status": "complete"},
        {"arch": "a2", "shape": "s", "mesh": "m", "status": "complete"},
        {"arch": "a3", "shape": "s", "mesh": "m", "status": "unsupported"},
    ])
    assert [r["arch"] for r in rows] == ["a2", "a1", "a3"]  # fastest first
    assert rows[0]["bound_s"] == 1.0 and rows[0]["best_point"] == {}
    assert rows[0]["feasible"] is True
    assert rows[-1]["bound_s"] is None  # no-datapoint cell preserved


def test_leaderboard_falls_back_to_fastest_infeasible(tmp_path):
    from repro.core.cost_db import CostDB, DataPoint
    from repro.launch.campaign import build_leaderboard

    db = CostDB(tmp_path / "db.jsonl")
    db.append(DataPoint(arch="a1", shape="s", mesh="m", point={"__key__": "k1"},
                        status="infeasible",
                        metrics={"bound_s": 9.0, "fits_hbm": False}))
    db.append(DataPoint(arch="a2", shape="s", mesh="m", point={"__key__": "k2"},
                        status="ok", metrics={"bound_s": 20.0, "fits_hbm": True}))
    rows = build_leaderboard(db, [
        {"arch": "a1", "shape": "s", "mesh": "m", "status": "complete"},
        {"arch": "a2", "shape": "s", "mesh": "m", "status": "complete"},
    ])
    # feasible cells outrank infeasible ones even when nominally slower
    assert [r["arch"] for r in rows] == ["a2", "a1"]
    assert rows[1]["feasible"] is False and rows[1]["bound_s"] == 9.0


def test_progress_counters_run_local_and_leaderboard_atomic(tmp_path):
    """A resumed campaign must report run-local counter deltas (not the
    whole persisted DB, which double-counts prior attempts), accumulate
    cumulative *_total across attempts via the prior heartbeat, and replace
    leaderboard.json atomically (a torn file from a killed attempt heals)."""
    import json as J

    from repro.core.cost_db import CostDB, DataPoint
    from repro.launch.campaign import read_progress, run_campaign

    out = tmp_path / "camp"
    out.mkdir()
    # debris of a prior SIGKILLed attempt: 3 DB rows, a heartbeat with
    # cumulative totals, and a torn (mid-write) leaderboard
    db = CostDB(out / "cost_db.jsonl")
    for i in range(3):
        db.append(DataPoint(arch="a", shape="s", mesh="m",
                            point={"__key__": f"k{i}"}, status="ok",
                            metrics={"bound_s": 1.0 + i, "fits_hbm": True}))
    (out / "progress.json").write_text(J.dumps(
        {"status": "running", "compiles_total": 7, "pruned_total": 2}))
    (out / "leaderboard.json").write_text('[{"arch": "a", "bo')  # torn

    summary = run_campaign([], [], None, "m", out_dir=out, workers=1,
                           verbose=False)
    # empty grid: no new work — deltas zero, totals carry prior attempts
    assert summary["evaluations"] == 0 and summary["compiles"] == 0
    assert summary["evaluations_total"] == 3
    assert summary["compiles_total"] == 7 and summary["pruned_total"] == 2
    final = read_progress(out)
    assert final["status"] == "done"
    assert final["evaluations"] == 0 and final["evaluations_total"] == 3
    assert final["compiles_total"] == 7 and final["pruned_total"] == 2
    assert final["cell_in_progress"] is None and final["iteration"] is None
    # the torn leaderboard was atomically replaced with valid JSON
    assert J.loads((out / "leaderboard.json").read_text()) == []
    assert list(out.glob("*.tmp")) == []


# ---------------------------------------------------------------------------
# batch evaluation == serial evaluation (and the pool path really runs)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_evaluate_batch_matches_serial(tmp_path):
    out = run_subprocess(f"""{TINY_PRELUDE}
        import json
        points = [base] + [p for p in template.neighbors(base)][:2]

        ser = Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}/a",
                        cache=DryRunCache(r"{tmp_path}/cs"), max_workers=1)
        serial = [ser.evaluate("qwen3-0.6b", "train_4k", p) for p in points]

        par = Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}/b",
                        cache=DryRunCache(r"{tmp_path}/cp"), max_workers=2)
        batch = par.evaluate_batch("qwen3-0.6b", "train_4k", points)

        assert len(serial) == len(batch) == len(points)
        VOLATILE = ("compile_s",)  # wall-clock; everything else is deterministic
        for s, b in zip(serial, batch):
            assert s.point == b.point and s.status == b.status, (s, b)
            ms = {{k: v for k, v in s.metrics.items() if k not in VOLATILE}}
            mb = {{k: v for k, v in b.metrics.items() if k not in VOLATILE}}
            assert ms == mb, (ms, mb)
        assert par.compile_count == len(points)
        print("BATCH_OK", [d.status for d in batch])
    """, n_devices=1, timeout=900)
    assert "BATCH_OK" in out


@pytest.mark.slow
def test_cache_hits_skip_recompilation(tmp_path):
    out = run_subprocess(f"""{TINY_PRELUDE}
        import repro.launch.dryrun as dryrun
        cache = DryRunCache(r"{tmp_path}/cache")
        ev = Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}/a",
                       cache=cache, max_workers=1)
        dp1 = ev.evaluate("qwen3-0.6b", "train_4k", base)
        assert dp1.status == "ok", dp1
        assert dryrun.N_COMPILES == 1 and ev.compile_count == 1

        # same (arch, shape, mesh, point): served from cache, no recompile
        dp2 = ev.evaluate("qwen3-0.6b", "train_4k", base)
        assert dryrun.N_COMPILES == 1 and ev.compile_count == 1
        assert cache.stats()["hits"] == 1
        assert dp2.status == dp1.status and dp2.metrics == dp1.metrics

        # a fresh evaluator over the same cache dir: disk hit, no recompile
        ev2 = Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}/a",
                        cache=DryRunCache(r"{tmp_path}/cache"), max_workers=1)
        dp3 = ev2.evaluate("qwen3-0.6b", "train_4k", base)
        assert dryrun.N_COMPILES == 1 and ev2.compile_count == 0
        assert dp3.metrics == dp1.metrics

        # corrupt the entry on disk: treated as a miss -> recompiled, and
        # the repaired entry serves the next evaluator without compiling
        entry = next(cache.root.glob("*.json"))
        entry.write_text(entry.read_text()[:40])
        ev3 = Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}/a",
                        cache=DryRunCache(r"{tmp_path}/cache"), max_workers=1)
        dp4 = ev3.evaluate("qwen3-0.6b", "train_4k", base)
        assert dryrun.N_COMPILES == 2 and ev3.compile_count == 1, dryrun.N_COMPILES
        assert dp4.status == "ok" and dp4.metrics["bound_s"] == dp1.metrics["bound_s"]
        ev4 = Evaluator(mesh, "tiny1x1", artifact_dir=r"{tmp_path}/a",
                        cache=DryRunCache(r"{tmp_path}/cache"), max_workers=1)
        assert ev4.evaluate("qwen3-0.6b", "train_4k", base).status == "ok"
        assert dryrun.N_COMPILES == 2 and ev4.compile_count == 0
        print("CACHE_OK")
    """, n_devices=1, timeout=900)
    assert "CACHE_OK" in out


# ---------------------------------------------------------------------------
# campaign sweep: grid, leaderboard, resume skips completed cells
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_campaign_sweep_and_resume(tmp_path):
    out = run_subprocess(f"""{TINY_PRELUDE}
        import json
        import repro.launch.dryrun as dryrun
        from pathlib import Path
        from repro.launch.campaign import run_campaign

        grid = dict(archs=["qwen3-0.6b", "stablelm-3b"],
                    shapes=["train_4k", "decode_32k"])
        s1 = run_campaign(**grid, mesh=mesh, mesh_name="tiny1x1",
                          out_dir=r"{tmp_path}/camp", iterations=1, budget=2,
                          workers=1, verbose=False)
        assert s1["ran"] == 4 and s1["resumed"] == 0, s1
        lb = json.loads(Path(s1["leaderboard"]).read_text())
        assert len(lb) == 4 and lb[0]["bound_s"] is not None
        assert all(r["status"] == "complete" for r in lb)
        compiles_before = dryrun.N_COMPILES
        assert compiles_before > 0

        # resume: every cell report exists -> no loop re-runs, no compiles
        s2 = run_campaign(**grid, mesh=mesh, mesh_name="tiny1x1",
                          out_dir=r"{tmp_path}/camp", iterations=1, budget=2,
                          workers=1, verbose=False)
        assert s2["ran"] == 0 and s2["resumed"] == 4, s2
        assert dryrun.N_COMPILES == compiles_before
        lb2 = json.loads(Path(s2["leaderboard"]).read_text())
        assert {{(r["arch"], r["shape"]) for r in lb2}} == \\
               {{(r["arch"], r["shape"]) for r in lb}}
        print("CAMPAIGN_OK", len(lb))
    """, n_devices=1, timeout=900)
    assert "CAMPAIGN_OK" in out


# ---------------------------------------------------------------------------
# determinism: the campaign is a function of (config, seed) — RPR002's
# contract, asserted end-to-end at the byte level
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_same_seed_campaigns_are_byte_identical(tmp_path):
    """Two runs of the identical campaign (same grid, same deterministic
    mock LLM, same default strategy seeds) must produce byte-identical
    leaderboards, and per-cell reports identical modulo the wall-clock
    audit fields (``ts`` timestamps, measured compile/wall seconds) that
    legitimately differ between runs. This is the regression guard behind
    the RPR002 lint rule: any module-level RNG sneaking into the
    search/rank path shows up here as a diff in the *decisions* — which
    points were proposed, evaluated, and ranked best."""
    out = run_subprocess(f"""{TINY_PRELUDE}
        import json
        from pathlib import Path
        from repro.launch.campaign import run_campaign

        common = dict(archs=["qwen3-0.6b", "stablelm-3b"],
                      shapes=["train_4k"], mesh=mesh, mesh_name="tiny1x1",
                      iterations=1, budget=2, workers=1, verbose=False)
        a = run_campaign(**common, out_dir=r"{tmp_path}/run_a")
        b = run_campaign(**common, out_dir=r"{tmp_path}/run_b")
        assert a["ran"] == 2 and b["ran"] == 2, (a, b)

        lb_a = Path(r"{tmp_path}/run_a/leaderboard.json").read_bytes()
        lb_b = Path(r"{tmp_path}/run_b/leaderboard.json").read_bytes()
        assert lb_a == lb_b, (lb_a[:400], lb_b[:400])

        VOLATILE = {{"ts", "compile_s", "wall_s", "walltime_s",
                     "elapsed_s", "done_at", "leased_at"}}
        def scrub(obj):
            if isinstance(obj, dict):
                return {{k: scrub(v) for k, v in sorted(obj.items())
                         if k not in VOLATILE}}
            if isinstance(obj, list):
                return [scrub(v) for v in obj]
            return obj

        reports_a = sorted(Path(r"{tmp_path}/run_a/reports").glob("*.json"))
        reports_b = sorted(Path(r"{tmp_path}/run_b/reports").glob("*.json"))
        assert [p.name for p in reports_a] == [p.name for p in reports_b]
        for pa, pb in zip(reports_a, reports_b):
            ra = scrub(json.loads(pa.read_text()))
            rb = scrub(json.loads(pb.read_text()))
            assert ra == rb, (pa.name, ra, rb)
        print("SAME_SEED_BYTE_IDENTICAL", len(reports_a))
    """, n_devices=1, timeout=900)
    assert "SAME_SEED_BYTE_IDENTICAL 2" in out
