"""Sharding-plan resolution: property tests for the system invariants."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.sharding.plan import ACT_KINDS, ShardingPlan, baseline_plan, baseline_rules


@pytest.fixture(scope="module")
def mesh22():
    return make_mesh((1, 1), ("data", "model"))


LOGICALS = [None, "batch", "seq", "embed", "heads", "kv_heads", "head_dim",
            "ffn", "vocab", "experts", "ssm_inner", "layers"]


class FakeMesh:
    """Shape-only stand-in so hypothesis can sweep mesh sizes w/o devices."""

    def __init__(self, shape):
        self.shape = shape


@settings(max_examples=60, deadline=None)
@given(
    dims=st.lists(st.tuples(st.integers(1, 512), st.sampled_from(LOGICALS)),
                  min_size=1, max_size=5),
    data=st.sampled_from([1, 2, 4, 16]),
    model=st.sampled_from([1, 2, 4, 16]),
)
def test_resolve_invariants(dims, data, model):
    """Every resolved PartitionSpec (a) only uses axes in the mesh, (b) never
    reuses a mesh axis, (c) only shards divisible dims."""
    mesh = FakeMesh({"data": data, "model": model})
    plan = ShardingPlan(rules=baseline_rules())
    shape = tuple(d for d, _ in dims)
    logical = tuple(l for _, l in dims)
    spec = plan.resolve(mesh, shape, logical)
    used = []
    for dim, part in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        size = 1
        for a in axes:
            assert a in mesh.shape
            assert a not in used, "mesh axis used twice in one tensor"
            used.append(a)
            size *= mesh.shape[a]
        assert dim % size == 0, "sharded a non-divisible dim"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_all_archs(arch):
    """Every param of every arch resolves to a valid spec on the prod mesh."""
    cfg = get_config(arch)
    values, logical = M.abstract_params(cfg)
    plan = baseline_plan(cfg, SHAPES[0])
    mesh = FakeMesh({"data": 16, "model": 16})
    specs = plan.param_specs(mesh, values, logical)
    for v, s in zip(jax.tree.leaves(values), jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        parts = tuple(s) + (None,) * (v.ndim - len(tuple(s)))
        for dim, part in zip(v.shape, parts):
            if part is None:
                continue
            axes = (part,) if isinstance(part, str) else part
            size = int(np.prod([{"data": 16, "model": 16}[a] for a in axes]))
            assert dim % size == 0, (arch, v.shape, s)


def test_act_kinds_cover_constrain_calls():
    for kind, dims in ACT_KINDS.items():
        assert all(d is None or isinstance(d, str) for d in dims)


def test_cache_specs_paths(mesh22):
    cfg = get_config("llama3-8b")
    cache = M.abstract_cache(cfg, 8, 128)
    plan = baseline_plan(cfg, SHAPES[2])
    specs = plan.cache_specs(FakeMesh({"data": 2, "model": 2}), cache)
    assert tuple(specs["k"]) [:3] == (None, "data", "model")  # layers,b,seq_kv
    assert tuple(specs["len"]) == ("data",)
