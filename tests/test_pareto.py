"""Unit tests for the multi-objective Pareto layer: dominance / ranking /
crowding / hypervolume (``repro.core.pareto``), objective extraction and
front queries (``repro.core.cost_db``), weight-arm scalarization
(``repro.search``), and the front-aware promotion planner. Pure python —
no jax, no subprocesses."""
import json
import math
import random

import pytest
from repro.core.cost_db import (CostDB, DataPoint, derive_objectives,
                                objective_value, objectives_of, pareto_rows)
from repro.core.pareto import (crowding_distances, dominates, front_order,
                               front_ranks, hypervolume)
from repro.core.promotion import plan_front_promotions, plan_promotions
from repro.search import WEIGHT_ARMS, make_strategy, weighted_objective

INF = float("inf")


# ---------------------------------------------------------------------------
# pareto.py primitives
# ---------------------------------------------------------------------------
def test_dominates_is_strict():
    assert dominates((1, 2), (2, 3))          # better in both
    assert dominates((1, 3), (2, 3))          # better in one, equal other
    assert not dominates((1, 2), (1, 2))      # equal never dominates
    assert not dominates((1, 4), (2, 3))      # trade-off: incomparable
    assert not dominates((2, 3), (1, 4))


def test_front_ranks_peels_layers():
    #  (1,4) and (4,1) and (2,2) are the front; (3,3) is dominated by (2,2);
    #  (5,5) is dominated by everything
    vecs = [(1, 4), (4, 1), (2, 2), (3, 3), (5, 5)]
    assert front_ranks(vecs) == [0, 0, 0, 1, 2]


def test_front_ranks_duplicates_share_rank():
    assert front_ranks([(1, 1), (1, 1), (2, 2)]) == [0, 0, 1]


def test_crowding_boundaries_are_infinite():
    d = crowding_distances([(0, 4), (1, 3), (2, 2), (4, 0)])
    assert d[0] == INF and d[-1] == INF
    assert 0 < d[1] < INF and 0 < d[2] < INF
    # interior spread: (1,3) is closer to its neighbors than (2,2) is to its
    assert d[1] == pytest.approx((2 - 0) / 4 + (4 - 2) / 4)


def test_front_order_is_insertion_order_invariant():
    rng = random.Random(7)
    vecs = [(rng.randrange(5), rng.randrange(5)) for _ in range(12)]
    ties = [f"t{i:02d}" for i in range(12)]
    base = front_order(vecs, ties)[0]
    canonical = [(vecs[i], ties[i]) for i in base]
    for _ in range(10):
        idx = list(range(12))
        rng.shuffle(idx)
        order = front_order([vecs[i] for i in idx], [ties[i] for i in idx])[0]
        assert [(vecs[idx[i]], ties[idx[i]]) for i in order] == canonical


def test_front_order_length_mismatch_raises():
    with pytest.raises(ValueError):
        front_order([(1, 2)], [])


def test_hypervolume_known_values():
    assert hypervolume([(1, 3), (3, 1)], (4, 4)) == pytest.approx(5.0)
    assert hypervolume([(1,)], (4,)) == pytest.approx(3.0)
    # dominated and duplicate points add nothing
    assert hypervolume([(1, 3), (3, 1), (3, 3), (1, 3)],
                       (4, 4)) == pytest.approx(5.0)
    # a point not strictly better than the reference contributes nothing
    assert hypervolume([(4, 1), (5, 5)], (4, 4)) == 0.0
    assert hypervolume([], (1, 1)) == 0.0


# ---------------------------------------------------------------------------
# objective extraction
# ---------------------------------------------------------------------------
def _plan_metrics(bound=1e-3, hbm=2e9, gib=0.5, mfu=0.3, fits=True):
    return {"bound_s": bound, "fits_hbm": fits, "hbm_bytes": hbm,
            "per_device_gib": gib, "mfu_at_bound": mfu}


def test_derive_objectives_plan_vs_kernel():
    plan = derive_objectives(_plan_metrics())
    assert plan == {"bound_s": 1e-3, "hbm_bytes": 2e9,
                    "vmem_bytes": 0.5 * 2**30, "flops_util": 0.3}
    kern = derive_objectives({"bound_s": 5e-5, "est_latency_us": 50.0,
                              "vmem_util": 0.4, "mxu_aligned": 1.0,
                              "vpu_aligned": 0.5, "fits_hbm": True})
    assert kern == {"bound_s": 5e-5, "vmem_util": 0.4, "flops_util": 0.75}
    assert derive_objectives({"fits_hbm": False}) == {}


def _dp(key, bound, ts=1.0, status="ok", fits=True, fidelity="dryrun",
        hbm=2e9, mfu=0.3):
    return DataPoint(arch="a1", shape="s1", mesh="m",
                     point={"remat": "full", "__key__": key}, status=status,
                     metrics=_plan_metrics(bound, hbm=hbm, mfu=mfu,
                                           fits=fits),
                     ts=ts, fidelity=fidelity)


def test_objective_value_gates_measured_and_infeasible():
    assert objective_value(_dp("k", 1e-3)) == 1e-3
    assert objective_value(_dp("k", 1e-3, fidelity="measured")) is None
    assert objective_value(_dp("k", 1e-3, fits=False)) is None
    assert objective_value(_dp("k", 1e-3), "hbm_bytes") == 2e9  # derived
    assert objective_value(_dp("k", 1e-3), "no_such") is None


def test_objectives_of_prefers_stored_vector():
    d = _dp("k", 1e-3)
    d.metrics["objectives"] = {"bound_s": 9.0, "flops_util": None}
    assert objectives_of(d) == {"bound_s": 9.0}


# ---------------------------------------------------------------------------
# pareto_rows / CostDB.front
# ---------------------------------------------------------------------------
def test_pareto_rows_never_fronts_a_dominated_row():
    # d2 dominates d3 in every objective; any insertion order must agree
    d1 = _dp("k1", 1e-3, ts=1.0, hbm=9e9, mfu=0.9)   # fast, hbm-hungry
    d2 = _dp("k2", 2e-3, ts=2.0, hbm=1e9, mfu=0.3)   # slower, lean
    d3 = _dp("k3", 3e-3, ts=3.0, hbm=2e9, mfu=0.2)   # dominated by d2
    rng = random.Random(3)
    rows = [d1, d2, d3]
    expected = None
    for _ in range(6):
        rng.shuffle(rows)
        ranked = pareto_rows(rows)
        got = [(d.point["__key__"], r) for d, r, _, _ in ranked]
        assert got == (expected := expected or got)
    by_key = dict(got)
    assert by_key["k1"] == 0 and by_key["k2"] == 0 and by_key["k3"] == 1


def test_pareto_rows_dedupes_earliest_per_key():
    early = _dp("k1", 5e-3, ts=1.0)
    late = _dp("k1", 1e-3, ts=2.0)
    ranked = pareto_rows([late, early])
    assert len(ranked) == 1 and ranked[0][0].ts == 1.0


def test_costdb_front_orders_and_truncates(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp("k1", 1e-3, ts=1.0, hbm=9e9, mfu=0.9))
    db.append(_dp("k2", 2e-3, ts=2.0, hbm=1e9, mfu=0.3))
    db.append(_dp("k3", 3e-3, ts=3.0, hbm=2e9, mfu=0.2))
    db.append(_dp("k4", 1e-4, ts=4.0, fidelity="measured"))  # never ranks
    front = db.front("a1", "s1", k=None, mesh="m")
    assert [d.point["__key__"] for d in front][-1] == "k3"  # dominated last
    assert len(db.front("a1", "s1", k=2, mesh="m")) == 2
    ranked = db.pareto("a1", "s1", mesh="m")
    assert [r for _, r, _, _ in ranked] == [0, 0, 1]


# ---------------------------------------------------------------------------
# scalarization weight arms
# ---------------------------------------------------------------------------
def test_weighted_objective_none_falls_back_to_bound():
    d = _dp("k", 1e-3)
    assert weighted_objective(d, None) == 1e-3
    assert weighted_objective(d, {}) == 1e-3
    assert weighted_objective(None, {"bound_s": 1.0}) is None
    assert weighted_objective(_dp("k", 1e-3, status="error"),
                              {"bound_s": 1.0}) is None


def test_weighted_objective_log_scale_and_maximize():
    d = _dp("k", 1e-3, mfu=0.5)
    assert weighted_objective(d, {"bound_s": 1.0}) == pytest.approx(-3.0)
    # flops_util is maximize-sense: its log term enters negated
    assert weighted_objective(d, {"flops_util": 1.0}) == pytest.approx(
        -math.log10(0.5))
    # keys the row lacks are skipped and the weights renormalize
    assert weighted_objective(d, {"bound_s": 1.0, "vmem_util": 5.0}
                              ) == pytest.approx(-3.0)
    # all-missing keys fall back to the raw bound
    assert weighted_objective(d, {"vmem_util": 1.0}) == 1e-3


def test_make_strategy_objective_modes():
    scalar = make_strategy("ensemble")
    assert [m.name for m in scalar.members] == ["greedy", "anneal", "evolve"]
    assert all(getattr(m, "weights", None) is None for m in scalar.members)
    par = make_strategy("ensemble", objective="pareto")
    names = [m.name for m in par.members]
    assert names[:3] == ["greedy", "anneal", "evolve"]
    assert {"anneal@latency", "anneal@memory", "evolve@latency",
            "evolve@memory"} <= set(names)
    arms = {m.name: m for m in par.members}
    assert arms["anneal@memory"].weights == WEIGHT_ARMS["memory"]
    # arm names ride into DB provenance so credit stays reconstructable
    assert par.credit.keys() >= set(names)
    assert make_strategy("anneal", objective="pareto").weights == \
        WEIGHT_ARMS["balanced"]
    assert make_strategy("anneal").weights is None
    with pytest.raises(ValueError):
        make_strategy("ensemble", objective="nope")


# ---------------------------------------------------------------------------
# front-aware promotions + leaderboard compat
# ---------------------------------------------------------------------------
def test_plan_front_promotions_contract_matches_plan_promotions():
    front = [_dp("k1", 1e-3), _dp("k2", 2e-3), _dp("k3", 3e-3)]
    promos = plan_front_promotions(front, {"k2"}, top_k=2)
    assert [d.point["__key__"] for d in promos] == ["k1", "k3"]
    assert plan_front_promotions(front, set(), top_k=2, budget_left=1) == \
        plan_promotions(front, set(), top_k=2, budget_left=1)
    assert plan_front_promotions(front, set(), top_k=0) == []


def test_build_leaderboard_scalar_mode_is_byte_identical(tmp_path):
    from repro.launch.campaign import build_leaderboard

    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp("k1", 1e-3, ts=1.0, hbm=9e9, mfu=0.9))
    db.append(_dp("k2", 2e-3, ts=2.0, hbm=1e9, mfu=0.3))
    cells = [{"arch": "a1", "shape": "s1", "mesh": "m",
              "status": "complete", "improvement": 0.5}]
    default = json.dumps(build_leaderboard(db, cells), sort_keys=True)
    scalar = json.dumps(build_leaderboard(db, cells, objective="bound_s"),
                        sort_keys=True)
    assert default == scalar
    assert "front" not in default
    par = build_leaderboard(db, cells, objective="pareto")
    row = par[0]
    assert row["objective"] == "pareto"
    assert row["front_size"] == len(row["front"]) == 2
    assert {e["point"]["remat"] for e in row["front"]} == {"full"}
    for e in row["front"]:
        assert set(e["objectives"]) == {"bound_s", "hbm_bytes",
                                        "vmem_bytes", "flops_util"}
        assert e["crowding"] is None or math.isfinite(e["crowding"])
    # strict JSON round-trips (inf crowding must serialize as null)
    assert json.loads(json.dumps(par)) == par
    with pytest.raises(ValueError):
        build_leaderboard(db, cells, objective="nope")
