"""Campaign prelude for tests/CI: tiny workloads whose *cells* are slow.

Chains the tiny prelude (64-token cells, see ``tiny_prelude.py``) and then
wraps ``repro.launch.dryrun.run_cell`` with a fixed ``time.sleep`` taken
from ``REPRO_TEST_EVAL_SLEEP_S`` (seconds, default 0). Every evaluation —
baseline included — pays the sleep, so a cell's wall time is guaranteed to
exceed a supervisor ``--hang-timeout`` chosen between one batch and one
cell, while each *iteration* stays far under it. This is the deterministic
reproduction of the hang-heal false-kill: with cell-boundary heartbeats the
orchestrator SIGKILLs the healthy shard; with iteration-granularity
heartbeats it must not (``tests/test_orchestrator.py`` asserts
``restarts == 0``).

Only valid with ``--workers 1``: pool workers are fresh spawn interpreters
that never execute this prelude.
"""
import os
import time
from pathlib import Path

# no __file__ here (the campaign exec()s this source); the env var that
# selected this prelude is the one reliable pointer back to this directory
_tiny = Path(os.environ["REPRO_CAMPAIGN_PRELUDE"]).resolve().with_name(
    "tiny_prelude.py")
exec(compile(_tiny.read_text(), str(_tiny), "exec"),
     {"__name__": "__repro_prelude__"})

import repro.launch.dryrun as _D  # noqa: E402

_SLEEP_S = float(os.environ.get("REPRO_TEST_EVAL_SLEEP_S", "0"))
_real_run_cell = _D.run_cell


def _slow_run_cell(*args, **kwargs):
    time.sleep(_SLEEP_S)
    return _real_run_cell(*args, **kwargs)


_D.run_cell = _slow_run_cell
