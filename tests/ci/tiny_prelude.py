"""Campaign prelude for tests/CI: shrink every workload to 64-token cells.

Executed by ``repro.launch.campaign.main()`` when ``REPRO_CAMPAIGN_PRELUDE``
points here (the orchestrator's shard subprocesses inherit the variable), so
a full sharded campaign compiles in seconds instead of hours. Mirrors the
``TINY_PRELUDE`` monkeypatch the in-process suite uses
(``tests/test_campaign_engine.py``): the shape registry entries are replaced
in place (every importer shares the dict) and the evaluator/dryrun config
lookups resolve to one reduced config regardless of arch name — cells keep
distinct (arch, shape) identities but all compile the same tiny model.

Only valid with ``--workers 1``: pool workers are fresh spawn interpreters
that never execute this prelude.
"""
import repro.configs as C
from repro.configs import get_config as _real_get, reduced
from repro.configs.base import ShapeCell

C.SHAPE_BY_NAME["train_4k"] = ShapeCell("train_4k", "train", 64, 8)
C.SHAPE_BY_NAME["decode_32k"] = ShapeCell("decode_32k", "decode", 64, 4)
_tiny = reduced(_real_get("qwen3-0.6b"))

import repro.core.evaluator as E  # noqa: E402
import repro.launch.dryrun as D  # noqa: E402

for _mod in (D, E):
    _mod.get_config = lambda name: _tiny
    _mod.SHAPE_BY_NAME = C.SHAPE_BY_NAME
