"""Campaign prelude for tests/CI: make exactly ONE shard a straggler.

Chains the tiny prelude (64-token cells, see ``tiny_prelude.py``) and then
wraps ``repro.launch.dryrun.run_cell`` with a fixed ``time.sleep`` — but
only when this process is the designated slow shard:
``REPRO_SHARD_INDEX`` (stamped by the orchestrator into every shard's
environment) equals ``REPRO_TEST_STRAGGLER_SHARD`` (default ``"0"``). The
sleep comes from ``REPRO_TEST_EVAL_SLEEP_S`` (seconds, default 0) and is
paid on every evaluation, baseline included.

This is the deterministic straggler scenario the work-stealing tests and
the ``bench_dse_throughput.py --straggler`` arm use: under the static
``--shard i/n`` cut, the whole campaign's wall-clock is the slow shard's;
under ``--queue``, the fast shard drains most of the grid and the
orchestrator steals the straggler's stuck cell, so at least one steal must
occur and the merged leaderboard must still match the static run
byte-for-byte.

Only valid with ``--workers 1``: pool workers are fresh spawn interpreters
that never execute this prelude.
"""
import os
import time
from pathlib import Path

# no __file__ here (the campaign exec()s this source); the env var that
# selected this prelude is the one reliable pointer back to this directory
_tiny = Path(os.environ["REPRO_CAMPAIGN_PRELUDE"]).resolve().with_name(
    "tiny_prelude.py")
exec(compile(_tiny.read_text(), str(_tiny), "exec"),
     {"__name__": "__repro_prelude__"})

_me = os.environ.get("REPRO_SHARD_INDEX")
_slow = os.environ.get("REPRO_TEST_STRAGGLER_SHARD", "0")

if _me is not None and _me == _slow:
    import repro.launch.dryrun as _D

    _SLEEP_S = float(os.environ.get("REPRO_TEST_EVAL_SLEEP_S", "0"))
    _real_run_cell = _D.run_cell

    def _slow_run_cell(*args, **kwargs):
        time.sleep(_SLEEP_S)
        return _real_run_cell(*args, **kwargs)

    _D.run_cell = _slow_run_cell
