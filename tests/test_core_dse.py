"""SECDA-DSE core: design space, cost DB, RAG, CoT, LLM stack, LoRA, MCP."""
import json
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import SHAPES, SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint, featurize, workload_features
from repro.core.cost_model import CostModel
from repro.core.design_space import (DIMENSIONS, PlanPoint, PlanTemplate,
                                     baseline_point, point_to_plan)
from repro.core.llm_client import MockLLM, parse_json_answer
from repro.core.llm_stack import LLMStack
from repro.core.cot import cot_propose
from repro.core import lora as lora_mod
from repro.core.rag import CodeIndex, DesignRetriever

import jax
import jax.numpy as jnp


MESH = {"data": 16, "model": 16}


# ---------------------------------------------------------------------------
# design space
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3-8b", "mixtral-8x7b", "llava-next-34b",
                                  "mamba2-780m"])
@pytest.mark.parametrize("shape", [s.name for s in SHAPES])
def test_baseline_point_always_legal(arch, shape):
    cfg, cell = get_config(arch), SHAPE_BY_NAME[shape]
    t = PlanTemplate(cfg, cell, MESH)
    p = baseline_point(cell, t)
    ok, why = t.validate(p)
    assert ok, (arch, shape, why)


def test_device_aware_ranges():
    # mixtral: 8 experts don't divide model=16 -> 'experts' excluded
    t = PlanTemplate(get_config("mixtral-8x7b"), SHAPES[0], MESH)
    assert "experts" not in t.dims()["expert_rule"]
    assert "expert_ffn" in t.dims()["expert_rule"]
    # llava: 56 heads don't divide 16 -> heads excluded, head_dim ok
    t2 = PlanTemplate(get_config("llava-next-34b"), SHAPES[0], MESH)
    assert "heads" not in t2.dims()["attn_rule"]
    assert "head_dim" in t2.dims()["attn_rule"]
    # mamba: attention-free
    t3 = PlanTemplate(get_config("mamba2-780m"), SHAPES[0], MESH)
    assert t3.dims()["attn_rule"] == ("none",)


def test_neighbors_stay_legal():
    cfg, cell = get_config("llama3-8b"), SHAPES[0]
    t = PlanTemplate(cfg, cell, MESH)
    p = baseline_point(cell, t)
    neigh = list(t.neighbors(p))
    assert len(neigh) >= 10
    for n in neigh:
        ok, why = t.validate(n)
        assert ok, why
        diff = [k for k in n.dims if n.dims[k] != p.dims.get(k)]
        assert len(diff) == 1  # single-dimension mutations


def test_point_to_plan_roundtrip():
    cfg, cell = get_config("llama3-8b"), SHAPES[0]
    t = PlanTemplate(cfg, cell, MESH)
    p = baseline_point(cell, t)
    plan = point_to_plan(cfg, cell, p)
    assert plan.rules["heads"] == "model"
    assert plan.remat == "full"
    p2 = PlanPoint(dims={**p.dims, "batch_rule": "data+model", "loss_chunk": 1024})
    plan2 = point_to_plan(cfg, cell, p2)
    assert plan2.rules["batch"] == ("data", "model")
    assert plan2.loss_chunk == 1024


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_points_legal(seed):
    import random

    cfg, cell = get_config("qwen3-moe-235b-a22b"), SHAPES[0]
    t = PlanTemplate(cfg, cell, MESH)
    for p in t.random_points(random.Random(seed), 3):
        ok, why = t.validate(p)
        assert ok, why


# ---------------------------------------------------------------------------
# cost DB + featurization
# ---------------------------------------------------------------------------
def _dp(arch="llama3-8b", shape="train_4k", status="ok", bound=1.0, **dims):
    cfg, cell = get_config(arch), SHAPE_BY_NAME[shape]
    t = PlanTemplate(cfg, cell, MESH)
    p = baseline_point(cell, t)
    point = {**p.dims, **dims, "__key__": PlanPoint(dims={**p.dims, **dims}).key()}
    return DataPoint(arch=arch, shape=shape, mesh="m", point=point, status=status,
                     metrics={"workload": workload_features(cfg, cell),
                              "bound_s": bound, "fits_hbm": status == "ok",
                              "dominant": "collective"})


def test_cost_db_roundtrip(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    db.append(_dp(bound=2.0))
    db.append(_dp(bound=1.0, remat="dots"))
    db.append(_dp(status="infeasible", bound=None, microbatches=2))
    db2 = CostDB(tmp_path / "db.jsonl")  # re-open from disk
    assert len(db2.all()) == 3
    best = db2.best("llama3-8b", "train_4k")
    assert best.metrics["bound_s"] == 1.0
    assert len(db2.query(status="infeasible")) == 1


@settings(max_examples=25, deadline=None)
@given(mb=st.sampled_from([1, 2, 4, 8]), lc=st.sampled_from([0, 512, 1024]))
def test_featurize_stable_finite(mb, lc):
    wl = workload_features(get_config("qwen3-8b"), SHAPES[0])
    f = featurize({"microbatches": mb, "loss_chunk": lc, "remat": "full"}, wl)
    assert f.shape == featurize({}, {}).shape
    assert np.isfinite(f).all()


def test_rag_retrieval_orders_by_similarity(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    near = _dp(bound=1.0)
    far = _dp(arch="mamba2-780m", shape="long_500k", bound=0.5, remat="none")
    db.append(near)
    db.append(far)
    wl = workload_features(get_config("llama3-8b"), SHAPES[0])
    got = DesignRetriever(db).retrieve(
        {k: v for k, v in near.point.items() if k != "__key__"}, wl, k=2)
    assert got[0].arch == "llama3-8b"


def test_code_index_retrieves_relevant_module(tmp_path):
    idx = CodeIndex(roots=[Path("src/repro/sharding")]).build()
    hits = idx.retrieve("PartitionSpec logical axes resolve mesh", k=2)
    assert hits and any("plan.py" in tag for tag, _ in hits)


# ---------------------------------------------------------------------------
# CoT + LLM stack
# ---------------------------------------------------------------------------
def test_cot_targets_dominant_term():
    cfg, cell = get_config("llama3-8b"), SHAPES[0]
    t = PlanTemplate(cfg, cell, MESH)
    p = baseline_point(cell, t)
    metrics = {"compute_s": 1.0, "memory_s": 2.0, "collective_s": 10.0,
               "bound_s": 10.0, "dominant": "collective", "fits_hbm": True}
    props, trace = cot_propose(dict(p.dims), metrics,
                               workload_features(cfg, cell),
                               template_dims=t.dims())
    assert props, trace.render()
    # top proposal must change a collective-targeting dimension
    top_change = {k for k, v in props[0].items() if v != p.dims.get(k)}
    assert top_change & {"batch_rule", "grad_compress", "seq_rule", "decode_attn"}
    assert "ANALYZE" in trace.render()


def test_llm_stack_propose_and_validate(tmp_path):
    cfg, cell = get_config("llama3-8b"), SHAPES[0]
    t = PlanTemplate(cfg, cell, MESH)
    p = baseline_point(cell, t)
    stack = LLMStack(client=MockLLM(), db=CostDB(tmp_path / "db.jsonl"))
    metrics = {"compute_s": 1.0, "memory_s": 9.0, "collective_s": 2.0,
               "bound_s": 9.0, "dominant": "memory", "fits_hbm": False,
               "per_device_gib": 30.0}
    valid, rejected, raw = stack.propose("llama3-8b", "train_4k", cfg, cell, t,
                                         p, metrics)
    assert valid, raw
    for v in valid:
        ok, why = t.validate(v)
        assert ok, why


def test_llm_stack_rejects_garbage_client(tmp_path):
    class Garbage:
        name = "garbage"

        def complete(self, prompt, system=""):
            return "I am a confused model with no json"

    cfg, cell = get_config("llama3-8b"), SHAPES[0]
    t = PlanTemplate(cfg, cell, MESH)
    stack = LLMStack(client=Garbage(), db=CostDB(tmp_path / "db.jsonl"))
    valid, rejected, _ = stack.propose(
        "llama3-8b", "train_4k", cfg, cell, t, baseline_point(cell, t),
        {"dominant": "memory", "fits_hbm": True})
    assert not valid and rejected and rejected[0].status == "rejected"


def test_nl_spec_to_vecmul_design():
    """Paper §4: the appendix prompt must yield a load-compute-store vecmul."""
    stack = LLMStack(client=MockLLM())
    spec = ("The accelerator should be able to take two input vectors: X and Y "
            "... perform an element-wise multiplication ... loading should be "
            "performed using a load module ... written back to main memory "
            "using a store module")
    design, raw = stack.generate_accelerator(spec, length=2048)
    assert design and design["kernel"] == "vecmul"
    assert design["modules"]["load"] and design["modules"]["store"]
    assert design["parameters"]["L"] == 2048


# ---------------------------------------------------------------------------
# LoRA + cost model
# ---------------------------------------------------------------------------
def test_lora_zero_init_is_identity():
    params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
    lora, _ = lora_mod.init_lora(params, jax.random.key(0), rank=2)
    eff = lora_mod.apply_lora(params, lora)
    np.testing.assert_allclose(eff["w"], params["w"])  # B=0 at init


def test_cost_model_learns_and_lora_freezes_base(tmp_path):
    db = CostDB(tmp_path / "db.jsonl")
    # synthetic: microbatches strongly correlate with bound
    for mb in (1, 2, 4, 8):
        for i in range(4):
            db.append(_dp(bound=10.0 / mb + 0.01 * i, microbatches=mb,
                          remat="dots" if i % 2 else "full"))
    cm = CostModel.create(in_dim=featurize({}, {}).shape[0])
    loss0 = cm.pretrain(db, steps=10)
    loss1 = cm.pretrain(db, steps=300)
    assert loss1 < loss0
    base_before = jax.tree.map(lambda x: np.asarray(x), cm.params)
    cm.finetune_lora(db, rank=2, steps=50)
    for a, b in zip(jax.tree.leaves(base_before), jax.tree.leaves(cm.params)):
        np.testing.assert_array_equal(a, b)  # base fully frozen
    assert cm.lora is not None
    # ranking: fewer-microbatch (higher bound) designs rank worse
    wl = workload_features(get_config("llama3-8b"), SHAPES[0])
    f_hi = featurize({"microbatches": 1, "remat": "full"}, wl)
    f_lo = featurize({"microbatches": 8, "remat": "full"}, wl)
    b, _ = cm.predict(np.stack([f_hi, f_lo]))
    assert b[0] > b[1]


# ---------------------------------------------------------------------------
# MCP registry
# ---------------------------------------------------------------------------
def test_mcp_registry_contract(tmp_path):
    from repro.core.mcp import Registry

    reg = Registry()

    @reg.register("echo", "echo tool", {"type": "object",
                                        "properties": {"x": {"type": "string"}},
                                        "required": ["x"]})
    def _echo(x):
        return {"x": x}

    assert reg.list_tools()[0]["name"] == "echo"
    assert reg.call("echo", x="hi") == {"x": "hi"}
    with pytest.raises(TypeError):
        reg.call("echo")
    with pytest.raises(KeyError):
        reg.call("nope")
    assert reg.log and reg.log[-1]["tool"] == "echo"
